//! Subcommand dispatch for the `lingcn` binary (hand-rolled arg parsing:
//! the offline environment has no clap — see the vendored-dependency note
//! in `rust/Cargo.toml`).
//!
//! Subcommands:
//!
//! | command | effect |
//! |---|---|
//! | `plan` | print the HE parameter plan (paper Table 6) |
//! | `calibrate [--quick]` | measure CKKS op costs and print the fitted model |
//! | `predict [--calibrate]` | predict paper-scale latencies for all variants |
//! | `infer --nl K [--encrypted] [--batch B] [--no-opt] [--threads N] [--limb-threads N] [--output-mode M] [--sgn-preset P] [--logit-bound B] [--allow-refresh[:R]]` | run one synthetic clip through a trained artifact; encrypted mode executes the compiled `HePlan` (`--threads` wavefront pool, `--limb-threads` per-limb NTT fan-out); `--batch B` slot-packs B clips into one ciphertext set (DESIGN.md S16); `--no-opt` skips the IR optimizer passes (DESIGN.md S17); `--output-mode logits\|argmax\|topk:K\|threshold:CLASS[:CUTOFF]` appends the composite-sign decision circuit (DESIGN.md S20) with `--sgn-preset fast\|balanced\|precise` depth/precision and logit bound `--logit-bound B`; `--allow-refresh[:R]` caps the chain and serves the overflow depth through in-process refresh rounds (DESIGN.md S21) |
//! | `serve [--tier plaintext\|he\|he-wire] [--batch B] [--no-opt] [--threads N] [--limb-threads N] [--workers N] [--requests M] [--status-json] [--output-mode M] [--sgn-preset P] [--logit-bound B]` | run the serving coordinator; `--tier he` serves real CKKS inference through cached compiled `HePlan`s (trusted single-process demo; `--batch B` coalesces up to B same-variant requests into one slot-batched ciphertext job; `--no-opt` serves raw unoptimized plans), `--tier he-wire` serves **only ciphertexts** against registered tenant eval keys, either over TCP (`--listen ADDR`, DESIGN.md S18) or as a file-driven roundtrip (`--dir D` / explicit `--eval-keys`/`--request`/`--response`) — the two modes are mutually exclusive; `--output-mode` compiles the serving plans for a decision mode (DESIGN.md S20) and refuses requests for any other mode; `--status-json` prints the DESIGN.md S19 machine-readable snapshot after the run summary (plaintext/he tiers); `--allow-refresh[:R]` (he/he-wire `--listen`) compiles serving plans on the refresh-capped chain and runs up to R interactive refresh rounds per request (DESIGN.md S21) |
//! | `keygen --nl K [--batch B] [--no-opt] [--seed S] [--out-dir D] [--output-mode M] [--sgn-preset P] [--logit-bound B] [--allow-refresh[:R]]` | client-side: generate a key pair for variant nl K; `--batch B` also covers the block-closed batch plan's rotations; `--output-mode` grows the chain and Galois set to cover the decision circuit too; `--allow-refresh[:R]` keys against the refresh-capped chain (must match the server's flag, DESIGN.md S21); writes the local secret key file and the server-shippable eval-key bundle |
//! | `encrypt --key F --input X.lgt --out R.cts [--batch B] [--output-mode M]` | client-side: encrypt a clip into a ciphertext request bundle (`--batch B` slot-packs B copies of the clip; `--output-mode` stamps the requested decision mode into the bundle, DESIGN.md S20) |
//! | `decrypt-logits --key F --in RESP.ct [--batch B] [--request R.cts]` | client-side: open the server's logits ciphertext and print the class scores (per clip when batched; `--request` cross-checks B against the request bundle) |
//! | `decrypt-decision --key F --in RESP.ct [--output-mode M] [--batch B] [--request R.cts]` | client-side: open a decision-mode response (DESIGN.md S20) and print the decision per clip; the mode comes from `--output-mode` or the request bundle (`--request`), which cross-check when both are given |
//! | `infer-remote --addr H:P [--nl K] [--batch B] [--tenant T] [--seed S] [--timeout-ms MS] [--output-mode M] [--sgn-preset P] [--logit-bound B] [--allow-refresh[:R]]` | client-side, against a `serve --tier he-wire --listen` server: keygen → register eval keys → encrypt → streamed upload → decrypt logits (or the decision, under `--output-mode`), all over one TCP connection (DESIGN.md S18/S20); `--allow-refresh[:R]` opens an interactive session that answers up to R server refresh rounds mid-inference (DESIGN.md S21) |
//! | `inspect [--plan-text F \| --artifacts [--nl K]] [--format json\|text\|dot] [--cost] [--profile N] [--batch B] [--no-opt] [--threads T]` | dump a compiled `HePlan` as a queryable graph (DESIGN.md S19): per-op kind/level/scale/wave, per-wave widths and critical path, per-pass optimizer accounting; `--cost` overlays reference cost-model predictions; `--profile N` (needs `--artifacts`) runs N profiled encrypted iterations first and overlays measured per-op latencies |
//! | `status --addr H:P [--tenant T] [--timeout-ms MS]` | fetch a live server's JSON status snapshot over TCP (DESIGN.md S19): metrics counters + latency histogram, per-plan profile EWMAs, plan-cache contents |
//!
//! The four-verb wire roundtrip (privacy boundary, DESIGN.md S15):
//!
//! ```text
//! lingcn keygen --nl 2 --out-dir wire
//! lingcn encrypt --key wire/client_nl2.key --input artifacts/example_input.lgt --out wire/request.cts
//! lingcn serve --tier he-wire --tenant alice --eval-keys wire/eval_nl2.keys \
//!              --request wire/request.cts --response wire/response.ct
//! lingcn decrypt-logits --key wire/client_nl2.key --in wire/response.ct
//! ```
//!
//! The same boundary over a real socket (DESIGN.md S18) is two commands:
//!
//! ```text
//! lingcn serve --tier he-wire --listen 127.0.0.1:7070     # terminal 1
//! lingcn infer-remote --addr 127.0.0.1:7070 --nl 2        # terminal 2
//! ```
//!
//! Encrypted decisions (DESIGN.md S20): pass the same `--output-mode` to
//! both sides and only the decision — not the logits — comes back:
//!
//! ```text
//! lingcn serve --tier he-wire --listen 127.0.0.1:7070 --output-mode argmax   # terminal 1
//! lingcn infer-remote --addr 127.0.0.1:7070 --nl 2 --output-mode argmax     # terminal 2
//! ```
//!
//! Interactive refresh (DESIGN.md S21): deep variants whose chain would
//! not fit compile onto the capped chain with refresh cut points; both
//! sides pass `--allow-refresh[:MAX_ROUNDS]` and the client re-encrypts
//! masked intermediates mid-inference on the same connection:
//!
//! ```text
//! lingcn serve --tier he-wire --listen 127.0.0.1:7070 \
//!              --output-mode argmax --sgn-preset precise --allow-refresh   # terminal 1
//! lingcn infer-remote --addr 127.0.0.1:7070 --nl 2 \
//!              --output-mode argmax --sgn-preset precise --allow-refresh   # terminal 2
//! ```
//!
//! `plan`, `calibrate` and `predict` are self-contained; `infer`,
//! `serve` and `keygen` need the `artifacts/` directory produced by the
//! python build path (`python/compile/aot.py`). Dispatch lives in the
//! library (not in `main.rs`) so the integration tests can exercise every
//! path in-process.

use crate::costmodel::predict::{predict, PaperVariant};
use crate::costmodel::OpCostModel;
use crate::he_infer::level_plan::paper_table6;
use crate::he_infer::Method;
use crate::util::ascii_table;
use anyhow::Result;
use std::path::Path;

/// Exit code for an unknown/missing subcommand.
pub const USAGE_EXIT: i32 = 2;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Parse the shared decision flags (DESIGN.md S20): `--output-mode
/// logits|argmax|topk:K|threshold:CLASS[:CUTOFF]`, `--sgn-preset
/// fast|balanced|precise`, `--logit-bound B`. Defaults mirror
/// [`PlanOptions::default`]; every verb validates these before touching
/// artifacts, keys, or sockets so a typo fails fast and clean.
fn decision_flags(
    args: &[String],
) -> Result<(crate::he_infer::OutputMode, crate::he_infer::SgnPreset, f64)> {
    let defaults = crate::he_infer::PlanOptions::default();
    let mode = match arg_value(args, "--output-mode") {
        Some(s) => crate::he_infer::OutputMode::parse(&s)?,
        None => defaults.output_mode,
    };
    let preset = match arg_value(args, "--sgn-preset") {
        Some(s) => crate::he_infer::SgnPreset::parse(&s)?,
        None => defaults.sgn_preset,
    };
    let bound: f64 = match arg_value(args, "--logit-bound") {
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("--logit-bound {s:?} is not a number"))?,
        None => defaults.logit_bound(),
    };
    anyhow::ensure!(
        bound.is_finite() && bound > 0.0,
        "--logit-bound must be a positive finite number, got {bound}"
    );
    Ok((mode, preset, bound))
}

/// Fold the decision flags into `opts` (shared by the key-generating and
/// plan-compiling verbs).
fn apply_decision_flags(
    opts: &mut crate::he_infer::PlanOptions,
    mode: crate::he_infer::OutputMode,
    preset: crate::he_infer::SgnPreset,
    bound: f64,
) {
    opts.output_mode = mode;
    opts.sgn_preset = preset;
    opts.set_logit_bound(bound);
}

/// Round budget when `--allow-refresh` is passed without an explicit
/// `:MAX_ROUNDS` suffix — generous for every shipped variant (the
/// deepest Precise-preset plan predicts 3 rounds on the capped chain)
/// while still bounding a runaway session.
const DEFAULT_REFRESH_ROUNDS: u32 = 4;

/// Parse `--allow-refresh[:MAX_ROUNDS]` (DESIGN.md S21): opt the plan
/// compiler into interactive refresh cut points — the chain caps at
/// [`crate::he_infer::REFRESH_CHAIN_CAP`] and depth past it round-trips
/// through the key holder — with an optional per-request round budget
/// (default [`DEFAULT_REFRESH_ROUNDS`]). Returns `None` when the flag is
/// absent. Client and server must agree on the flag: it changes the
/// serving chain geometry, so keys generated without it do not match a
/// refresh-compiled plan.
fn refresh_flag(args: &[String]) -> Result<Option<u32>> {
    for a in args {
        if a == "--allow-refresh" {
            return Ok(Some(DEFAULT_REFRESH_ROUNDS));
        }
        if let Some(n) = a.strip_prefix("--allow-refresh:") {
            let rounds: u32 = n.parse().map_err(|_| {
                anyhow::anyhow!("--allow-refresh:{n}: MAX_ROUNDS is not a positive integer")
            })?;
            anyhow::ensure!(
                rounds >= 1,
                "--allow-refresh:0 permits no rounds — drop the flag to \
                 compile monolithically instead"
            );
            return Ok(Some(rounds));
        }
    }
    Ok(None)
}

/// Dispatch one invocation. Returns the process exit code on success
/// (0 for a completed subcommand, [`USAGE_EXIT`] for an unknown one, with
/// usage printed to stderr); runtime failures surface as `Err`.
pub fn run(args: &[String]) -> Result<i32> {
    match args.first().map(String::as_str) {
        Some("plan") => cmd_plan().map(|()| 0),
        Some("calibrate") => cmd_calibrate(args).map(|()| 0),
        Some("predict") => cmd_predict(args).map(|()| 0),
        Some("infer") => cmd_infer(args).map(|()| 0),
        Some("serve") => cmd_serve(args).map(|()| 0),
        Some("keygen") => cmd_keygen(args).map(|()| 0),
        Some("encrypt") => cmd_encrypt(args).map(|()| 0),
        Some("decrypt-logits") => cmd_decrypt_logits(args).map(|()| 0),
        Some("decrypt-decision") => cmd_decrypt_decision(args).map(|()| 0),
        Some("infer-remote") => cmd_infer_remote(args).map(|()| 0),
        Some("inspect") => cmd_inspect(args).map(|()| 0),
        Some("status") => cmd_status(args).map(|()| 0),
        _ => {
            eprintln!(
                "usage: lingcn <plan|calibrate|predict|infer|serve|keygen|encrypt|decrypt-logits|decrypt-decision|infer-remote|inspect|status> [options]"
            );
            Ok(USAGE_EXIT)
        }
    }
}

fn cmd_plan() -> Result<()> {
    let rows: Vec<Vec<String>> = paper_table6()
        .into_iter()
        .map(|(name, p)| {
            vec![
                name,
                p.n.to_string(),
                p.log_q.to_string(),
                p.scale_bits.to_string(),
                p.q0_bits.to_string(),
                p.levels.to_string(),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(&["Model", "N", "Q", "p", "q0", "Mult Level"], &rows)
    );
    Ok(())
}

fn cmd_calibrate(args: &[String]) -> Result<()> {
    let m = if args.iter().any(|a| a == "--quick") {
        eprintln!("measuring CKKS op latencies (quick: N = 2^11 only)...");
        OpCostModel::calibrate_quick()?
    } else {
        eprintln!("measuring CKKS op latencies (N = 2^11..2^13)...");
        OpCostModel::calibrate()?
    };
    println!("fitted cost model (seconds per feature unit):");
    println!("  rot_a     = {:.3e}  (N·log2 N·limbs²)", m.rot_a);
    println!("  cmult_a   = {:.3e}  (N·log2 N·limbs²)", m.cmult_a);
    println!("  pmult_a   = {:.3e}  (N·limbs)", m.pmult_a);
    println!("  add_a     = {:.3e}  (N·limbs)", m.add_a);
    println!("  rescale_a = {:.3e}  (N·log2 N·limbs)", m.rescale_a);
    Ok(())
}

fn cmd_predict(args: &[String]) -> Result<()> {
    let cost = if args.iter().any(|a| a == "--calibrate") {
        OpCostModel::calibrate()?
    } else {
        OpCostModel::reference()
    };
    let mut rows = Vec::new();
    for nl in [6usize, 5, 4, 3, 2, 1] {
        for method in [Method::LinGcn, Method::CryptoGcn] {
            let label = match method {
                Method::LinGcn => "LinGCN",
                Method::CryptoGcn => "CryptoGCN",
            };
            let r = predict(&PaperVariant::stgcn_3_128(nl, method), &cost)?;
            rows.push(vec![
                label.to_string(),
                nl.to_string(),
                r.he.n.to_string(),
                r.he.levels.to_string(),
                format!("{:.1}", r.total_s),
            ]);
        }
    }
    println!(
        "{}",
        ascii_table(&["Method", "NL", "N", "Levels", "Pred latency (s)"], &rows)
    );
    Ok(())
}

fn cmd_infer(args: &[String]) -> Result<()> {
    let nl: usize = arg_value(args, "--nl").unwrap_or_else(|| "2".into()).parse()?;
    let encrypted = args.iter().any(|a| a == "--encrypted");
    let optimize = !args.iter().any(|a| a == "--no-opt");
    let batch: usize = arg_value(args, "--batch").unwrap_or_else(|| "1".into()).parse()?;
    let threads: usize = arg_value(args, "--threads").unwrap_or_else(|| "1".into()).parse()?;
    let limb_threads: usize =
        arg_value(args, "--limb-threads").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    anyhow::ensure!(
        batch == 1 || encrypted,
        "--batch only applies to --encrypted (slot-packed ciphertext batching)"
    );
    let (mode, preset, bound) = decision_flags(args)?;
    anyhow::ensure!(
        matches!(mode, crate::he_infer::OutputMode::Logits) || encrypted,
        "--output-mode only applies to --encrypted (the decision circuit \
         runs on ciphertexts, DESIGN.md S20)"
    );
    let refresh = refresh_flag(args)?;
    anyhow::ensure!(
        refresh.is_none() || encrypted,
        "--allow-refresh only applies to --encrypted (refresh cut points \
         are a ciphertext-chain construct, DESIGN.md S21)"
    );
    let dir = Path::new("artifacts");
    let model = crate::stgcn::StgcnModel::load(
        &dir.join(format!("model_nl{nl}.lgt")),
        crate::graph::Graph::ntu_rgbd(),
    )?;
    let ex = crate::util::tensorio::TensorFile::load(&dir.join("example_input.lgt"))?;
    let x = &ex.get("x")?.data;
    let t0 = std::time::Instant::now();
    if encrypted {
        // decision modes grow the chain by the sign circuit's depth
        let levels_full = 2 * model.layers.len()
            + 2
            + nl
            + crate::he_infer::sgn::decision_levels(mode, preset, model.num_classes());
        let params = crate::ckks::CkksParams {
            n: 1 << 11,
            q0_bits: 50,
            scale_bits: 33,
            // --allow-refresh caps the chain and round-trips the depth
            // past it through the (here: in-process) key holder, matching
            // the geometry session_geometry derives for keygen/serving
            levels: match refresh {
                Some(_) => levels_full.min(crate::he_infer::REFRESH_CHAIN_CAP),
                None => levels_full,
            },
            special_bits: 55,
            allow_insecure: true,
        };
        crate::ckks::set_limb_parallelism(limb_threads);
        let mut opts = crate::he_infer::PlanOptions { batch, optimize, ..Default::default() };
        apply_decision_flags(&mut opts, mode, preset, bound);
        if let Some(rounds) = refresh {
            opts.allow_refresh = true;
            opts.max_refresh_rounds = rounds;
        }
        let sess =
            crate::he_infer::PrivateInferenceSession::new_with_options(&model, params, 7, opts)?;
        // demo batch: the example clip slot-packed B times (a deployment
        // packs B *distinct* client clips)
        let clips: Vec<&[f64]> = (0..batch).map(|_| x.as_slice()).collect();
        let input = sess.encrypt_input_batch(&model, &clips)?;
        let (out, refresh_stats) = if refresh.is_some() {
            let (out, stats) = sess.infer_parallel_refresh(&input, threads)?;
            (out, Some(stats))
        } else {
            (sess.infer_parallel(&input, threads)?, None)
        };
        if matches!(mode, crate::he_infer::OutputMode::Logits) {
            let per_clip = sess.decrypt_logits_batch(&model, &out);
            let wall = t0.elapsed();
            for (b, logits) in per_clip.iter().enumerate() {
                let arg = crate::util::argmax(logits);
                println!(
                    "mode=encrypted nl={nl} clip={b}/{batch} predicted_class={arg}\nlogits={logits:?}"
                );
            }
            println!(
                "batch={batch} latency={wall:?} ({:.2} clips/s)",
                batch as f64 / wall.as_secs_f64()
            );
        } else {
            let per_clip = sess.decrypt_decision_batch(&model, &out);
            let wall = t0.elapsed();
            for (b, decision) in per_clip.iter().enumerate() {
                println!(
                    "mode=encrypted nl={nl} clip={b}/{batch} output_mode={mode} \
                     preset={} decision={decision}",
                    preset.name()
                );
            }
            println!(
                "batch={batch} latency={wall:?} ({:.2} clips/s)",
                batch as f64 / wall.as_secs_f64()
            );
        }
        if let Some(s) = refresh_stats {
            println!(
                "refresh_rounds={} masked_cts={} refresh_wait={}us (trusted \
                 in-process refresh, DESIGN.md S21)",
                s.rounds, s.cts, s.wait_us
            );
        }
    } else {
        let logits = model.forward(x)?;
        let arg = crate::util::argmax(&logits);
        println!(
            "mode=plaintext nl={nl} predicted_class={arg} latency={:?}\nlogits={logits:?}",
            t0.elapsed()
        );
    }
    Ok(())
}

/// Fill `words` from the OS entropy device; errors when none is
/// available (minimal containers, non-unix) so callers can warn loudly
/// instead of silently degrading.
fn os_entropy(words: &mut [u64]) -> Result<()> {
    use std::io::Read;
    let mut f = std::fs::File::open("/dev/urandom")?;
    for w in words.iter_mut() {
        let mut buf = [0u8; 8];
        f.read_exact(&mut buf)?;
        *w = u64::from_le_bytes(buf);
    }
    Ok(())
}

/// Weak last-resort entropy (time + pid). Never a shared constant, but
/// searchable by an attacker who can bound the invocation window —
/// every caller must warn when falling back to this.
fn weak_entropy() -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    crate::util::fnv1a_u64([nanos, std::process::id() as u64])
}

/// Shared seed policy for the key-generating verbs (`keygen`,
/// `infer-remote`): explicit `--seed` is reproducible (tests) but
/// derivable, and warns; the default seeds full 256-bit state from the OS
/// entropy device, with a loud time+pid fallback.
fn keygen_from_args(
    args: &[String],
    model: &crate::stgcn::StgcnModel,
    variant: &str,
    opts: crate::he_infer::PlanOptions,
) -> Result<(crate::wire::ClientKeys, crate::wire::EvalKeySet)> {
    if let Some(s) = arg_value(args, "--seed") {
        eprintln!(
            "WARNING: --seed makes the secret key derivable from the seed \
             value; use only for reproducible tests"
        );
        crate::wire::keygen(model, variant, opts, s.parse()?)
    } else {
        let mut state = [0u64; 4];
        match os_entropy(&mut state) {
            Ok(()) => crate::wire::keygen_with_state(model, variant, opts, state),
            Err(_) => {
                eprintln!(
                    "WARNING: no OS entropy device (/dev/urandom); falling \
                     back to time+pid seeding — the generated key is \
                     guessable by an attacker who can bound the invocation \
                     time. Do not use this key for anything but local \
                     testing."
                );
                crate::wire::keygen(model, variant, opts, weak_entropy())
            }
        }
    }
}

fn cmd_keygen(args: &[String]) -> Result<()> {
    let nl: usize = arg_value(args, "--nl").unwrap_or_else(|| "2".into()).parse()?;
    let batch: usize = arg_value(args, "--batch").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    let (mode, preset, bound) = decision_flags(args)?;
    let out_dir = std::path::PathBuf::from(
        arg_value(args, "--out-dir").unwrap_or_else(|| "wire".into()),
    );
    let variant = format!("lingcn-nl{nl}");
    let model = crate::stgcn::StgcnModel::load(
        &Path::new("artifacts").join(format!("model_nl{nl}.lgt")),
        crate::graph::Graph::ntu_rgbd(),
    )?;
    // --batch B: the Galois set also covers the block-closed batch-B
    // plan's wrap rotations, so this tenant can ship slot-packed bundles.
    // --no-opt keys against the raw plan — same rotation set either way
    // (the optimizer never adds or drops a distinct step), kept for
    // symmetry with the serving flags.
    let optimize = !args.iter().any(|a| a == "--no-opt");
    // --output-mode M: the chain gains the decision circuit's levels and
    // the Galois set its tournament rotations (DESIGN.md S20), so this
    // tenant's requests can ask for encrypted decisions
    let mut opts = crate::he_infer::PlanOptions { batch, optimize, ..Default::default() };
    apply_decision_flags(&mut opts, mode, preset, bound);
    // --allow-refresh[:R]: key against the refresh-capped chain
    // (DESIGN.md S21) — the serving side must pass the same flag, and
    // requests must open an interactive session (`infer-remote
    // --allow-refresh`) for plans that carry cut points
    let refresh = refresh_flag(args)?;
    if let Some(rounds) = refresh {
        opts.allow_refresh = true;
        opts.max_refresh_rounds = rounds;
    }
    let (client, key_set) = keygen_from_args(args, &model, &variant, opts)?;
    std::fs::create_dir_all(&out_dir)?;
    use crate::wire::WireSerialize;
    let client_path = out_dir.join(format!("client_nl{nl}.key"));
    let eval_path = out_dir.join(format!("eval_nl{nl}.keys"));
    let client_bytes = client.to_bytes();
    let eval_bytes = key_set.to_bytes();
    write_secret_file(&client_path, &client_bytes)?;
    std::fs::write(&eval_path, &eval_bytes)?;
    if let Some(rounds) = refresh {
        println!(
            "refresh=enabled max_rounds={rounds} (chain capped at {} levels; \
             serve and infer-remote must pass --allow-refresh too)",
            crate::he_infer::REFRESH_CHAIN_CAP
        );
    }
    println!(
        "variant={variant} output_mode={mode} galois_keys={} client_key={} ({} bytes, \
         SECRET — keep local) eval_keys={} ({} bytes, ship to server)",
        key_set.keys.galois.len(),
        client_path.display(),
        client_bytes.len(),
        eval_path.display(),
        eval_bytes.len(),
    );
    Ok(())
}

fn ensure_parent_dir(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    Ok(())
}

/// Write the client's secret key file owner-readable only — it contains
/// the CKKS secret key, and a default-umask file would hand it to every
/// local user.
fn write_secret_file(path: &Path, bytes: &[u8]) -> Result<()> {
    // write-to-temp + rename: a crash mid-write must never destroy the
    // only copy of the secret key and its advanced RNG state (recovering
    // by re-running keygen with the same seed would reset the encryption
    // randomness stream — the reuse this file exists to prevent). The
    // temp name is per-process so concurrent writers can't rename each
    // other's partial files into place.
    let tmp = {
        let mut os = path.as_os_str().to_os_string();
        os.push(format!(".{}.tmp", std::process::id()));
        std::path::PathBuf::from(os)
    };
    match std::fs::remove_file(&tmp) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
        Err(e) => return Err(e.into()),
    }
    let mut opts = std::fs::OpenOptions::new();
    opts.write(true).create_new(true);
    // created 0600: mode() only applies at creation, which create_new
    // guarantees — the secret never transits a world-readable file
    #[cfg(unix)]
    {
        use std::os::unix::fs::OpenOptionsExt;
        opts.mode(0o600);
    }
    let mut f = opts.open(&tmp)?;
    std::io::Write::write_all(&mut f, bytes)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn cmd_encrypt(args: &[String]) -> Result<()> {
    use crate::wire::WireSerialize;
    let key_path = arg_value(args, "--key")
        .ok_or_else(|| anyhow::anyhow!("encrypt requires --key <client key file>"))?;
    let input = arg_value(args, "--input")
        .unwrap_or_else(|| "artifacts/example_input.lgt".into());
    let out = arg_value(args, "--out").unwrap_or_else(|| "wire/request.cts".into());
    let batch: usize = arg_value(args, "--batch").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    // --output-mode M stamps the requested decision mode into the bundle
    // (DESIGN.md S20); the serving tier refuses any mode its plans were
    // not compiled for
    let mode = match arg_value(args, "--output-mode") {
        Some(s) => crate::he_infer::OutputMode::parse(&s)?,
        None => crate::he_infer::OutputMode::Logits,
    };
    let client = crate::wire::ClientKeys::from_bytes(&std::fs::read(Path::new(&key_path))?)?;
    // mix per-invocation entropy: two encrypts from the same persisted
    // RNG state (concurrent runs, a restored backup) would otherwise
    // draw identical (v, e0, e1), leaking plaintext differences
    let mut mix = [0u64; 4];
    if os_entropy(&mut mix).is_err() {
        eprintln!(
            "WARNING: no OS entropy device; mixing time+pid only — do not \
             run concurrent encrypts from one key file on this platform"
        );
        mix[0] = weak_entropy();
    }
    client.mix_entropy(mix);
    let ex = crate::util::tensorio::TensorFile::load(Path::new(&input))?;
    let x = &ex.get("x")?.data;
    // demo batch: the clip slot-packed B times (a deployment packs B
    // distinct clips; the bundle carries the batch size either way)
    let bundle = if batch > 1 {
        let clips: Vec<&[f64]> = (0..batch).map(|_| x.as_slice()).collect();
        client.encrypt_request_batch(&clips)?
    } else {
        client.encrypt_request(x)?
    }
    .with_mode(mode);
    // persist the advanced RNG state too (defense in depth)
    write_secret_file(Path::new(&key_path), &client.to_bytes())?;
    let bytes = bundle.to_bytes();
    ensure_parent_dir(Path::new(&out))?;
    std::fs::write(Path::new(&out), &bytes)?;
    println!(
        "variant={} ciphertexts={} batch={} output_mode={} wrote {out} ({} bytes)",
        client.variant,
        bundle.cts.len(),
        bundle.batch,
        bundle.mode,
        bytes.len()
    );
    Ok(())
}

fn cmd_decrypt_logits(args: &[String]) -> Result<()> {
    use crate::wire::WireSerialize;
    let key_path = arg_value(args, "--key")
        .ok_or_else(|| anyhow::anyhow!("decrypt-logits requires --key <client key file>"))?;
    let in_path = arg_value(args, "--in").unwrap_or_else(|| "wire/response.ct".into());
    let mut batch: usize = arg_value(args, "--batch").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    // cross-check against the request bundle when available: the bare
    // response ciphertext does not carry its batch, and a wrong --batch
    // would confidently decode padded (zero) copies as predictions
    if let Some(req_path) = arg_value(args, "--request") {
        let bundle = crate::wire::CtBundle::from_bytes(&std::fs::read(Path::new(&req_path))?)?;
        if args.iter().any(|a| a == "--batch") {
            anyhow::ensure!(
                batch == bundle.batch,
                "--batch {batch} disagrees with the request bundle's slot-batch \
                 size {} ({req_path})",
                bundle.batch
            );
        }
        batch = bundle.batch;
    } else if batch > 1 {
        eprintln!(
            "WARNING: --batch {batch} is not validated against the request — \
             if it exceeds what `encrypt --batch` packed, the extra clips \
             decode CKKS noise on zeroed copies, not real predictions \
             (pass --request <request.cts> to cross-check)"
        );
    }
    let client = crate::wire::ClientKeys::from_bytes(&std::fs::read(Path::new(&key_path))?)?;
    let ct = crate::ckks::Ciphertext::from_bytes(&std::fs::read(Path::new(&in_path))?)?;
    if batch > 1 {
        for (b, logits) in client.decrypt_logits_batch(&ct, batch)?.iter().enumerate() {
            let arg = crate::util::argmax(logits);
            println!(
                "variant={} clip={b}/{batch} predicted_class={arg}\nlogits={logits:?}",
                client.variant
            );
        }
    } else {
        let logits = client.decrypt_logits(&ct)?;
        let arg = crate::util::argmax(&logits);
        println!("variant={} predicted_class={arg}\nlogits={logits:?}", client.variant);
    }
    Ok(())
}

/// `decrypt-logits`' decision-mode sibling (DESIGN.md S20): open a
/// decision-mode response ciphertext and print the per-clip decision.
/// The mode comes from `--output-mode` or the request bundle
/// (`--request`, which carries it since wire v3) — when both are given
/// they must agree, like `--batch`.
fn cmd_decrypt_decision(args: &[String]) -> Result<()> {
    use crate::wire::WireSerialize;
    let key_path = arg_value(args, "--key")
        .ok_or_else(|| anyhow::anyhow!("decrypt-decision requires --key <client key file>"))?;
    let in_path = arg_value(args, "--in").unwrap_or_else(|| "wire/response.ct".into());
    let mut batch: usize = arg_value(args, "--batch").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    let mut mode = match arg_value(args, "--output-mode") {
        Some(s) => Some(crate::he_infer::OutputMode::parse(&s)?),
        None => None,
    };
    if let Some(req_path) = arg_value(args, "--request") {
        let bundle = crate::wire::CtBundle::from_bytes(&std::fs::read(Path::new(&req_path))?)?;
        if args.iter().any(|a| a == "--batch") {
            anyhow::ensure!(
                batch == bundle.batch,
                "--batch {batch} disagrees with the request bundle's slot-batch \
                 size {} ({req_path})",
                bundle.batch
            );
        }
        batch = bundle.batch;
        match mode {
            Some(m) => anyhow::ensure!(
                m == bundle.mode,
                "--output-mode {m} disagrees with the request bundle's mode {} ({req_path})",
                bundle.mode
            ),
            None => mode = Some(bundle.mode),
        }
    }
    let mode = mode.ok_or_else(|| {
        anyhow::anyhow!(
            "decrypt-decision needs the response's output mode: pass \
             --output-mode MODE or --request <request.cts> (the bundle \
             carries the mode it asked for)"
        )
    })?;
    let client = crate::wire::ClientKeys::from_bytes(&std::fs::read(Path::new(&key_path))?)?;
    let ct = crate::ckks::Ciphertext::from_bytes(&std::fs::read(Path::new(&in_path))?)?;
    for (b, decision) in client.decrypt_decision_batch(&ct, batch, mode)?.iter().enumerate() {
        println!(
            "variant={} clip={b}/{batch} output_mode={mode} decision={decision}",
            client.variant
        );
    }
    Ok(())
}

/// Shared `--tier he-wire` executor flags, parsed and validated before
/// any artifact or socket work so flag errors stay fast and clean.
struct WireServeFlags {
    workers: usize,
    threads: usize,
    limb_threads: usize,
    capacity: usize,
    optimize: bool,
    mode: crate::he_infer::OutputMode,
    preset: crate::he_infer::SgnPreset,
    bound: f64,
    /// `--allow-refresh[:MAX_ROUNDS]` (DESIGN.md S21): compile serving
    /// plans with refresh cut points and cap each session's round budget.
    refresh: Option<u32>,
}

fn wire_serve_flags(args: &[String]) -> Result<WireServeFlags> {
    // wire batching is client-side: the request bundle carries its own
    // batch size, so a server-side --batch here would only mislead
    anyhow::ensure!(
        arg_value(args, "--batch").is_none(),
        "--batch does not apply to --tier he-wire: the slot-batch size \
         travels in the request bundle (use `encrypt --batch B`)"
    );
    let (mode, preset, bound) = decision_flags(args)?;
    Ok(WireServeFlags {
        workers: arg_value(args, "--workers").unwrap_or_else(|| "2".into()).parse()?,
        threads: arg_value(args, "--threads").unwrap_or_else(|| "1".into()).parse()?,
        limb_threads: arg_value(args, "--limb-threads").unwrap_or_else(|| "1".into()).parse()?,
        capacity: arg_value(args, "--registry-capacity").unwrap_or_else(|| "64".into()).parse()?,
        optimize: !args.iter().any(|a| a == "--no-opt"),
        mode,
        preset,
        bound,
        refresh: refresh_flag(args)?,
    })
}

/// The wire tier has two modes: `--listen ADDR` serves the TCP protocol
/// (DESIGN.md S18); `--dir D` (or explicit `--eval-keys`/`--request`/
/// `--response`) runs the offline file-driven roundtrip. They are
/// mutually exclusive — previously the file path silently won.
fn cmd_serve_wire(args: &[String]) -> Result<()> {
    let flags = wire_serve_flags(args)?;
    let listen = arg_value(args, "--listen");
    let file_flags: Vec<&str> = ["--dir", "--eval-keys", "--request", "--response"]
        .into_iter()
        .filter(|f| args.iter().any(|a| a == f))
        .collect();
    if listen.is_some() && !file_flags.is_empty() {
        anyhow::bail!(
            "--listen (network serving) and {} (file-driven roundtrip) are \
             mutually exclusive — pick one mode",
            file_flags.join("/")
        );
    }
    match listen {
        Some(addr) => cmd_serve_wire_listen(args, &addr, flags),
        None if file_flags.is_empty() => anyhow::bail!(
            "serve --tier he-wire needs a mode: --listen <addr> for network \
             serving, or --dir <dir> (or explicit --eval-keys/--request/\
             --response) for the file-driven roundtrip"
        ),
        None => cmd_serve_wire_files(args, flags),
    }
}

/// Resolve the single `<prefix>*<suffix>` file in `dir` (e.g. the
/// eval-key bundle `keygen --out-dir` wrote there).
fn find_unique_file(dir: &Path, prefix: &str, suffix: &str) -> Result<std::path::PathBuf> {
    let mut matches: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("scanning {}: {e}", dir.display()))?
        .filter_map(|entry| entry.ok())
        .map(|entry| entry.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(prefix) && n.ends_with(suffix))
        })
        .collect();
    matches.sort();
    match matches.len() {
        0 => anyhow::bail!(
            "no {prefix}*{suffix} in {} (run `lingcn keygen --out-dir` first?)",
            dir.display()
        ),
        1 => Ok(matches.remove(0)),
        n => anyhow::bail!(
            "{n} {prefix}*{suffix} candidates in {} — pass --eval-keys explicitly",
            dir.display()
        ),
    }
}

/// File-driven mode: register the tenant's eval keys, run the ciphertext
/// request file through the coordinator, write the logits ciphertext.
/// The server side of this function only ever handles serialized keys
/// and ciphertexts — no secret key, no plaintext clip.
fn cmd_serve_wire_files(args: &[String], flags: WireServeFlags) -> Result<()> {
    use crate::wire::WireSerialize;
    let WireServeFlags {
        workers,
        threads,
        limb_threads,
        capacity,
        optimize,
        mode,
        preset,
        bound,
        refresh,
    } = flags;
    // refresh rounds need a live client on the other end of a socket; the
    // offline file roundtrip has nobody to re-encrypt the cut points
    anyhow::ensure!(
        refresh.is_none(),
        "--allow-refresh needs the interactive TCP tier (--listen): the \
         file-driven roundtrip cannot round-trip refresh cut points \
         (DESIGN.md S21)"
    );
    let tenant = arg_value(args, "--tenant").unwrap_or_else(|| "cli-tenant".into());
    // --dir D fills in the conventional names (keygen's eval_nl*.keys,
    // encrypt's request.cts); explicit flags override file-by-file
    let dir = arg_value(args, "--dir").map(std::path::PathBuf::from);
    let eval_keys = match arg_value(args, "--eval-keys") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            let d = dir.as_deref().ok_or_else(|| {
                anyhow::anyhow!(
                    "serve --tier he-wire requires --eval-keys <file> (or \
                     --dir <dir> containing an eval_nl*.keys bundle)"
                )
            })?;
            find_unique_file(d, "eval", ".keys")?
        }
    };
    let request = match arg_value(args, "--request") {
        Some(p) => std::path::PathBuf::from(p),
        None => match &dir {
            Some(d) => d.join("request.cts"),
            None => anyhow::bail!(
                "serve --tier he-wire requires --request <file> (or --dir <dir> \
                 containing request.cts)"
            ),
        },
    };
    let response = match arg_value(args, "--response") {
        Some(p) => std::path::PathBuf::from(p),
        None => match &dir {
            Some(d) => d.join("response.ct"),
            None => std::path::PathBuf::from("wire/response.ct"),
        },
    };
    let (eval_keys, request, response) =
        (eval_keys.as_path(), request.as_path(), response.as_path());

    crate::ckks::set_limb_parallelism(limb_threads);
    let cost = OpCostModel::reference();
    let metrics = std::sync::Arc::new(crate::coordinator::Metrics::default());
    let (router, mut executor) = crate::coordinator::wire_from_artifacts(
        Path::new("artifacts"),
        &cost,
        threads,
        capacity,
        metrics.clone(),
    )?;
    // tenant keys cover the same rotation set either way (the optimizer
    // never adds or drops a distinct step), so --no-opt is safe here
    executor.set_optimize(optimize);
    // --output-mode M: the serving plans append the decision circuit and
    // any request for a different mode is refused typed (DESIGN.md S20)
    executor.set_output_mode(mode, preset, bound);
    let key_set = crate::wire::EvalKeySet::from_bytes(&std::fs::read(eval_keys)?)?;
    let variant = key_set.variant.clone();
    let tenant_params = key_set.params.clone();
    executor.register(&tenant, key_set)?;
    println!("registered tenant {tenant} for variant {variant}");

    let bundle = crate::wire::CtBundle::from_bytes(&std::fs::read(request)?)?;
    // reject cross-chain requests up front: ciphertexts encrypted under a
    // different parameter set would otherwise decode as silent garbage
    bundle.check_params(&tenant_params)?;
    let coord = crate::coordinator::Coordinator::start_with_metrics(
        router,
        std::sync::Arc::new(executor),
        metrics,
        workers,
        8,
        std::time::Duration::from_millis(2),
    );
    let t0 = std::time::Instant::now();
    let hash = Some(bundle.params_hash);
    let batch = bundle.batch;
    // the bundle's stamped mode travels with the request; the executor
    // refuses it typed if the serving plans were compiled for another
    let req_mode = bundle.mode;
    let resp = coord
        .infer_blocking_encrypted(tenant, Some(variant), bundle.cts, hash, batch, req_mode, None)?;
    if let Some(err) = resp.error {
        coord.shutdown();
        anyhow::bail!("encrypted request failed: {err}");
    }
    let ct = resp.ct_logits.expect("ok response carries the logits ciphertext");
    let bytes = ct.to_bytes();
    ensure_parent_dir(response)?;
    std::fs::write(response, &bytes)?;
    println!(
        "served variant={} output_mode={req_mode} queue={:?} exec={:?} wall={:?} → wrote {} \
         ({} bytes)",
        resp.variant,
        resp.queue,
        resp.exec,
        t0.elapsed(),
        response.display(),
        bytes.len()
    );
    println!("{}", coord.metrics.summary());
    coord.shutdown();
    Ok(())
}

/// Network mode (DESIGN.md S18): bind the TCP tier over the coordinator
/// and serve until killed. Tenants register their own eval keys over the
/// socket, so no `--eval-keys`/`--tenant` here.
fn cmd_serve_wire_listen(args: &[String], addr: &str, flags: WireServeFlags) -> Result<()> {
    let WireServeFlags {
        workers,
        threads,
        limb_threads,
        capacity,
        optimize,
        mode,
        preset,
        bound,
        refresh,
    } = flags;
    // net knobs, validated before artifact loading
    let read_timeout_ms: u64 =
        arg_value(args, "--read-timeout-ms").unwrap_or_else(|| "30000".into()).parse()?;
    let write_timeout_ms: u64 =
        arg_value(args, "--write-timeout-ms").unwrap_or_else(|| "30000".into()).parse()?;
    let max_conns: usize =
        arg_value(args, "--max-conns-per-tenant").unwrap_or_else(|| "64".into()).parse()?;
    let max_inflight: usize =
        arg_value(args, "--max-inflight-per-tenant").unwrap_or_else(|| "32".into()).parse()?;

    crate::ckks::set_limb_parallelism(limb_threads);
    let cost = OpCostModel::reference();
    let metrics = std::sync::Arc::new(crate::coordinator::Metrics::default());
    let (router, mut executor) = crate::coordinator::wire_from_artifacts(
        Path::new("artifacts"),
        &cost,
        threads,
        capacity,
        metrics.clone(),
    )?;
    executor.set_optimize(optimize);
    executor.set_output_mode(mode, preset, bound);
    // --allow-refresh[:R]: serving plans compile on the refresh-capped
    // chain and requests must open an interactive session (DESIGN.md S21)
    if let Some(rounds) = refresh {
        executor.set_refresh(true, rounds);
    }
    let executor = std::sync::Arc::new(executor);
    println!("variants:");
    for v in router.variants() {
        println!(
            "  {} nl={} acc={:.3} predicted-HE-latency={:.0}s",
            v.name, v.nl, v.accuracy, v.latency_s
        );
    }
    let dyn_exec: std::sync::Arc<dyn crate::coordinator::InferenceExecutor> = executor.clone();
    let coord = crate::coordinator::Coordinator::start_with_metrics(
        router,
        dyn_exec,
        metrics.clone(),
        workers,
        8,
        std::time::Duration::from_millis(2),
    );
    let backend =
        std::sync::Arc::new(crate::wire::net::CoordinatorBackend::new(executor, coord));
    let mut cfg = crate::wire::net::NetConfig {
        read_timeout: std::time::Duration::from_millis(read_timeout_ms),
        write_timeout: std::time::Duration::from_millis(write_timeout_ms),
        max_conns_per_tenant: max_conns,
        max_inflight_per_tenant: max_inflight,
        ..Default::default()
    };
    // the net tier clamps every session's announced round budget to the
    // flag's value — a client asking for more silently gets the ceiling
    if let Some(rounds) = refresh {
        cfg.max_refresh_rounds = rounds;
    }
    let server = crate::wire::net::NetServer::bind(addr, backend, metrics.clone(), cfg)?;
    println!(
        "listening on {} ({workers} workers, {threads} plan-exec threads, \
         output_mode={mode}{}; tenants register eval keys over the socket; \
         ctrl-c to stop)",
        server.local_addr(),
        match refresh {
            Some(rounds) => format!(", refresh=on max_rounds={rounds}"),
            None => String::new(),
        }
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(60));
        println!("{}", metrics.summary());
    }
}

/// Client side of the TCP tier: keygen → register → encrypt → streamed
/// upload → decrypt, all against a remote `serve --tier he-wire --listen`
/// process. Only eval keys and ciphertexts leave this process.
fn cmd_infer_remote(args: &[String]) -> Result<()> {
    let addr = arg_value(args, "--addr")
        .ok_or_else(|| anyhow::anyhow!("infer-remote requires --addr <host:port>"))?;
    let nl: usize = arg_value(args, "--nl").unwrap_or_else(|| "2".into()).parse()?;
    let batch: usize = arg_value(args, "--batch").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    let tenant = arg_value(args, "--tenant").unwrap_or_else(|| "cli-tenant".into());
    let timeout_ms: u64 =
        arg_value(args, "--timeout-ms").unwrap_or_else(|| "600000".into()).parse()?;
    let input =
        arg_value(args, "--input").unwrap_or_else(|| "artifacts/example_input.lgt".into());
    let optimize = !args.iter().any(|a| a == "--no-opt");
    // validate the decision flags before keygen/socket work; the same
    // mode must be passed to the server's `serve --output-mode`
    let (mode, preset, bound) = decision_flags(args)?;
    // --allow-refresh[:R] must match the server's flag too: it changes
    // the chain the keys are generated against (DESIGN.md S21)
    let refresh = refresh_flag(args)?;
    let variant = format!("lingcn-nl{nl}");
    let model = crate::stgcn::StgcnModel::load(
        &Path::new("artifacts").join(format!("model_nl{nl}.lgt")),
        crate::graph::Graph::ntu_rgbd(),
    )?;
    let mut opts = crate::he_infer::PlanOptions { batch, optimize, ..Default::default() };
    apply_decision_flags(&mut opts, mode, preset, bound);
    if let Some(rounds) = refresh {
        opts.allow_refresh = true;
        opts.max_refresh_rounds = rounds;
    }
    let (client, key_set) = keygen_from_args(args, &model, &variant, opts)?;
    let ex = crate::util::tensorio::TensorFile::load(Path::new(&input))?;
    let x = &ex.get("x")?.data;

    let t0 = std::time::Instant::now();
    let mut conn = crate::wire::net::Client::connect_with(
        &addr,
        &tenant,
        std::time::Duration::from_millis(timeout_ms),
    )?;
    conn.register(&key_set)?;
    let t_registered = t0.elapsed();
    // demo batch: the example clip slot-packed B times (a deployment
    // packs B distinct clips)
    let bundle = if batch > 1 {
        let clips: Vec<&[f64]> = (0..batch).map(|_| x.as_slice()).collect();
        client.encrypt_request_batch(&clips)?
    } else {
        client.encrypt_request(x)?
    }
    .with_mode(mode);
    // interactive session: the client answers the server's refresh
    // rounds (decrypt masked cut points, re-encrypt at the chain top)
    // before the final response arrives on the same connection
    let (reply, rounds_served) = match refresh {
        Some(rounds) => conn.infer_with_refresh(Some(&variant), &bundle, &client, rounds)?,
        None => (conn.infer(Some(&variant), &bundle)?, 0),
    };
    let wall = t0.elapsed();
    if matches!(mode, crate::he_infer::OutputMode::Logits) {
        for (b, logits) in
            client.decrypt_logits_batch(&reply.ct_logits, batch)?.iter().enumerate()
        {
            let arg = crate::util::argmax(logits);
            println!(
                "variant={} clip={b}/{batch} predicted_class={arg}\nlogits={logits:?}",
                reply.variant
            );
        }
    } else {
        // decision mode: only the decision comes back — the raw logits
        // never leave the server's decision circuit (DESIGN.md S20)
        for (b, decision) in
            client.decrypt_decision_batch(&reply.ct_logits, batch, mode)?.iter().enumerate()
        {
            println!(
                "variant={} clip={b}/{batch} output_mode={mode} decision={decision}",
                reply.variant
            );
        }
    }
    if refresh.is_some() {
        println!("refresh_rounds={rounds_served} (client re-encrypted the masked cut points)");
    }
    println!(
        "remote={addr} register={t_registered:?} queue={:?} exec={:?} wall={wall:?} \
         sent={}B received={}B",
        reply.queue, reply.exec, conn.bytes_out, conn.bytes_in
    );
    Ok(())
}

/// Plan inspector (DESIGN.md S19). Flag validation runs before any file
/// or HE work so `inspect --format bogus` fails fast and clean.
fn cmd_inspect(args: &[String]) -> Result<()> {
    let format = arg_value(args, "--format").unwrap_or_else(|| "text".into());
    anyhow::ensure!(
        matches!(format.as_str(), "json" | "text" | "dot"),
        "unknown --format {format} (expected json|text|dot)"
    );
    let plan_text = arg_value(args, "--plan-text");
    let artifacts = args.iter().any(|a| a == "--artifacts");
    anyhow::ensure!(
        !(plan_text.is_some() && artifacts),
        "--plan-text and --artifacts are mutually exclusive — pick one plan source"
    );
    anyhow::ensure!(
        plan_text.is_some() || artifacts,
        "inspect needs a plan source: --plan-text <file> or --artifacts [--nl K]"
    );
    let profile_runs: usize = match arg_value(args, "--profile") {
        Some(n) => n.parse()?,
        None => 0,
    };
    anyhow::ensure!(
        profile_runs == 0 || artifacts,
        "--profile requires --artifacts (profiling executes real encrypted \
         inference against a trained variant)"
    );
    let cost = args.iter().any(|a| a == "--cost").then(OpCostModel::reference);

    // source 1: a serialized plan file (`HePlan::to_text` format) — no
    // artifacts, keys, or HE work involved
    if let Some(path) = plan_text {
        let plan = crate::he_infer::HePlan::from_text(&std::fs::read_to_string(Path::new(&path))?)?;
        let out = match format.as_str() {
            "json" => crate::he_infer::inspect::plan_json(&plan, None, cost.as_ref())?,
            "dot" => crate::he_infer::inspect::plan_dot(&plan)?,
            _ => crate::he_infer::inspect::plan_text(&plan, None, cost.as_ref())?,
        };
        println!("{out}");
        return Ok(());
    }

    // source 2: compile the trained variant exactly as `infer --encrypted`
    // serves it, optionally profiling N real encrypted iterations
    let nl: usize = arg_value(args, "--nl").unwrap_or_else(|| "2".into()).parse()?;
    let batch: usize = arg_value(args, "--batch").unwrap_or_else(|| "1".into()).parse()?;
    let threads: usize = arg_value(args, "--threads").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    let optimize = !args.iter().any(|a| a == "--no-opt");
    let (mode, preset, bound) = decision_flags(args)?;
    let dir = Path::new("artifacts");
    let model = crate::stgcn::StgcnModel::load(
        &dir.join(format!("model_nl{nl}.lgt")),
        crate::graph::Graph::ntu_rgbd(),
    )?;
    let params = crate::ckks::CkksParams {
        n: 1 << 11,
        q0_bits: 50,
        scale_bits: 33,
        levels: 2 * model.layers.len()
            + 2
            + nl
            + crate::he_infer::sgn::decision_levels(mode, preset, model.num_classes()),
        special_bits: 55,
        allow_insecure: true,
    };
    let mut opts = crate::he_infer::PlanOptions { batch, optimize, ..Default::default() };
    apply_decision_flags(&mut opts, mode, preset, bound);
    let sess = crate::he_infer::PrivateInferenceSession::new_with_options(&model, params, 7, opts)?;
    if profile_runs > 0 {
        let ex = crate::util::tensorio::TensorFile::load(&dir.join("example_input.lgt"))?;
        let x = &ex.get("x")?.data;
        let clips: Vec<&[f64]> = (0..batch).map(|_| x.as_slice()).collect();
        let input = sess.encrypt_input_batch(&model, &clips)?;
        eprintln!("profiling {profile_runs} encrypted iteration(s) of nl={nl}...");
        crate::he_infer::set_profiling(true);
        let runs: Result<Vec<_>> =
            (0..profile_runs).map(|_| sess.infer_parallel(&input, threads)).collect();
        crate::he_infer::set_profiling(false);
        runs?;
    }
    let profile = (profile_runs > 0).then(|| sess.prepared().profile.clone());
    let out = match format.as_str() {
        "json" => crate::he_infer::inspect::plan_json(
            &sess.plan,
            profile.as_deref(),
            cost.as_ref(),
        )?,
        "dot" => crate::he_infer::inspect::plan_dot(&sess.plan)?,
        _ => crate::he_infer::inspect::plan_text(&sess.plan, profile.as_deref(), cost.as_ref())?,
    };
    println!("{out}");
    Ok(())
}

/// Probe a live `serve --tier he-wire --listen` server's status endpoint
/// (DESIGN.md S19) and print the JSON snapshot.
fn cmd_status(args: &[String]) -> Result<()> {
    let addr = arg_value(args, "--addr")
        .ok_or_else(|| anyhow::anyhow!("status requires --addr <host:port>"))?;
    let tenant = arg_value(args, "--tenant").unwrap_or_else(|| "status-probe".into());
    let timeout_ms: u64 =
        arg_value(args, "--timeout-ms").unwrap_or_else(|| "30000".into()).parse()?;
    let mut conn = crate::wire::net::Client::connect_with(
        &addr,
        &tenant,
        std::time::Duration::from_millis(timeout_ms),
    )?;
    println!("{}", conn.status()?);
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let tier = arg_value(args, "--tier").unwrap_or_else(|| "plaintext".into());
    if tier == "he-wire" {
        return cmd_serve_wire(args);
    }
    let workers: usize = arg_value(args, "--workers").unwrap_or_else(|| "2".into()).parse()?;
    let requests: usize = arg_value(args, "--requests").unwrap_or_else(|| "64".into()).parse()?;
    let threads: usize = arg_value(args, "--threads").unwrap_or_else(|| "1".into()).parse()?;
    let batch: usize = arg_value(args, "--batch").unwrap_or_else(|| "1".into()).parse()?;
    let optimize = !args.iter().any(|a| a == "--no-opt");
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    let (mode, preset, bound) = decision_flags(args)?;
    let refresh = refresh_flag(args)?;
    let limb_threads: usize =
        arg_value(args, "--limb-threads").unwrap_or_else(|| "1".into()).parse()?;
    // limb fan-out composes multiplicatively with the plan-executor pool
    // and the worker pool — keep the product near the core count
    crate::ckks::set_limb_parallelism(limb_threads);
    let cost = OpCostModel::reference();
    let metrics = std::sync::Arc::new(crate::coordinator::Metrics::default());
    let (router, executor): (
        crate::coordinator::Router,
        std::sync::Arc<dyn crate::coordinator::InferenceExecutor>,
    ) = match tier.as_str() {
        "plaintext" => {
            anyhow::ensure!(batch <= 1, "--batch is a slot-packing knob of --tier he");
            anyhow::ensure!(optimize, "--no-opt is a HePlan knob of --tier he");
            anyhow::ensure!(
                matches!(mode, crate::he_infer::OutputMode::Logits),
                "--output-mode is a decision-circuit knob of --tier he|he-wire \
                 (DESIGN.md S20)"
            );
            anyhow::ensure!(
                refresh.is_none(),
                "--allow-refresh is a ciphertext-chain knob of --tier \
                 he|he-wire (DESIGN.md S21)"
            );
            let (router, exec) = crate::coordinator::from_artifacts(Path::new("artifacts"), &cost)?;
            (router, std::sync::Arc::new(exec))
        }
        "he" => {
            let (router, mut exec) = crate::coordinator::he_from_artifacts(
                Path::new("artifacts"),
                &cost,
                threads,
                batch,
            )?;
            exec.set_optimize(optimize);
            exec.set_output_mode(mode, preset, bound);
            // trusted tier: refresh rounds resolve in-process through
            // LocalRefresh (the executor holds the keys), so this is the
            // single-machine demo of the capped-chain geometry
            if let Some(rounds) = refresh {
                exec.set_refresh(true, rounds);
            }
            exec.set_metrics(metrics.clone());
            (router, std::sync::Arc::new(exec))
        }
        other => anyhow::bail!("unknown tier {other} (expected plaintext|he|he-wire)"),
    };
    println!("variants:");
    for v in router.variants() {
        println!(
            "  {} nl={} acc={:.3} predicted-HE-latency={:.0}s",
            v.name, v.nl, v.accuracy, v.latency_s
        );
    }
    let coord = crate::coordinator::Coordinator::start_with_metrics(
        router,
        executor,
        metrics,
        workers,
        8,
        std::time::Duration::from_millis(2),
    );
    let ex = crate::util::tensorio::TensorFile::load(Path::new("artifacts/example_input.lgt"))?;
    let x = ex.get("x")?.data.clone();
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..requests {
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        coord.submit(crate::coordinator::Request {
            clip: x.clone(),
            latency_budget_s: if i % 3 == 0 { Some(1000.0) } else { None },
            resp: tx,
        })?;
        rxs.push(rx);
    }
    for rx in rxs {
        let _ = rx.recv()?;
    }
    let wall = t0.elapsed();
    println!("{}", coord.metrics.summary());
    println!(
        "{requests} requests in {wall:?} → {:.1} req/s ({tier} tier, {workers} workers, \
         {threads} plan-exec threads)",
        requests as f64 / wall.as_secs_f64()
    );
    // machine-readable tail for scripts: the same snapshot the TCP
    // tier's STATUS frame serves (DESIGN.md S19)
    if args.iter().any(|a| a == "--status-json") {
        println!("{}", coord.status_json());
    }
    coord.shutdown();
    Ok(())
}
