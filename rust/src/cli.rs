//! Subcommand dispatch for the `lingcn` binary (hand-rolled arg parsing:
//! the offline environment has no clap — see the vendored-dependency note
//! in `rust/Cargo.toml`).
//!
//! Subcommands:
//!
//! | command | effect |
//! |---|---|
//! | `plan` | print the HE parameter plan (paper Table 6) |
//! | `calibrate [--quick]` | measure CKKS op costs and print the fitted model |
//! | `predict [--calibrate]` | predict paper-scale latencies for all variants |
//! | `infer --nl K [--encrypted] [--threads N] [--limb-threads N]` | run one synthetic clip through a trained artifact; encrypted mode executes the compiled `HePlan` (`--threads` wavefront pool, `--limb-threads` per-limb NTT fan-out) |
//! | `serve [--tier plaintext\|he] [--threads N] [--limb-threads N] [--workers N] [--requests M]` | run the serving coordinator; `--tier he` serves real CKKS inference through cached compiled `HePlan`s, `--threads` sizing the per-request plan-executor pool and `--limb-threads` the per-limb fan-out |
//!
//! `plan`, `calibrate` and `predict` are self-contained; `infer` and
//! `serve` need the `artifacts/` directory produced by the python build
//! path (`python/compile/aot.py`). Dispatch lives in the library (not in
//! `main.rs`) so the integration tests can exercise every path in-process.

use crate::costmodel::predict::{predict, PaperVariant};
use crate::costmodel::OpCostModel;
use crate::he_infer::level_plan::paper_table6;
use crate::he_infer::Method;
use crate::util::ascii_table;
use anyhow::Result;
use std::path::Path;

/// Exit code for an unknown/missing subcommand.
pub const USAGE_EXIT: i32 = 2;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Dispatch one invocation. Returns the process exit code on success
/// (0 for a completed subcommand, [`USAGE_EXIT`] for an unknown one, with
/// usage printed to stderr); runtime failures surface as `Err`.
pub fn run(args: &[String]) -> Result<i32> {
    match args.first().map(String::as_str) {
        Some("plan") => cmd_plan().map(|()| 0),
        Some("calibrate") => cmd_calibrate(args).map(|()| 0),
        Some("predict") => cmd_predict(args).map(|()| 0),
        Some("infer") => cmd_infer(args).map(|()| 0),
        Some("serve") => cmd_serve(args).map(|()| 0),
        _ => {
            eprintln!("usage: lingcn <plan|calibrate|predict|infer|serve> [options]");
            Ok(USAGE_EXIT)
        }
    }
}

fn cmd_plan() -> Result<()> {
    let rows: Vec<Vec<String>> = paper_table6()
        .into_iter()
        .map(|(name, p)| {
            vec![
                name,
                p.n.to_string(),
                p.log_q.to_string(),
                p.scale_bits.to_string(),
                p.q0_bits.to_string(),
                p.levels.to_string(),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(&["Model", "N", "Q", "p", "q0", "Mult Level"], &rows)
    );
    Ok(())
}

fn cmd_calibrate(args: &[String]) -> Result<()> {
    let m = if args.iter().any(|a| a == "--quick") {
        eprintln!("measuring CKKS op latencies (quick: N = 2^11 only)...");
        OpCostModel::calibrate_quick()?
    } else {
        eprintln!("measuring CKKS op latencies (N = 2^11..2^13)...");
        OpCostModel::calibrate()?
    };
    println!("fitted cost model (seconds per feature unit):");
    println!("  rot_a     = {:.3e}  (N·log2 N·limbs²)", m.rot_a);
    println!("  cmult_a   = {:.3e}  (N·log2 N·limbs²)", m.cmult_a);
    println!("  pmult_a   = {:.3e}  (N·limbs)", m.pmult_a);
    println!("  add_a     = {:.3e}  (N·limbs)", m.add_a);
    println!("  rescale_a = {:.3e}  (N·log2 N·limbs)", m.rescale_a);
    Ok(())
}

fn cmd_predict(args: &[String]) -> Result<()> {
    let cost = if args.iter().any(|a| a == "--calibrate") {
        OpCostModel::calibrate()?
    } else {
        OpCostModel::reference()
    };
    let mut rows = Vec::new();
    for nl in [6usize, 5, 4, 3, 2, 1] {
        for method in [Method::LinGcn, Method::CryptoGcn] {
            let label = match method {
                Method::LinGcn => "LinGCN",
                Method::CryptoGcn => "CryptoGCN",
            };
            let r = predict(&PaperVariant::stgcn_3_128(nl, method), &cost)?;
            rows.push(vec![
                label.to_string(),
                nl.to_string(),
                r.he.n.to_string(),
                r.he.levels.to_string(),
                format!("{:.1}", r.total_s),
            ]);
        }
    }
    println!(
        "{}",
        ascii_table(&["Method", "NL", "N", "Levels", "Pred latency (s)"], &rows)
    );
    Ok(())
}

fn cmd_infer(args: &[String]) -> Result<()> {
    let nl: usize = arg_value(args, "--nl").unwrap_or_else(|| "2".into()).parse()?;
    let encrypted = args.iter().any(|a| a == "--encrypted");
    let threads: usize = arg_value(args, "--threads").unwrap_or_else(|| "1".into()).parse()?;
    let limb_threads: usize =
        arg_value(args, "--limb-threads").unwrap_or_else(|| "1".into()).parse()?;
    let dir = Path::new("artifacts");
    let model = crate::stgcn::StgcnModel::load(
        &dir.join(format!("model_nl{nl}.lgt")),
        crate::graph::Graph::ntu_rgbd(),
    )?;
    let ex = crate::util::tensorio::TensorFile::load(&dir.join("example_input.lgt"))?;
    let x = &ex.get("x")?.data;
    let t0 = std::time::Instant::now();
    let logits = if encrypted {
        let params = crate::ckks::CkksParams {
            n: 1 << 11,
            q0_bits: 50,
            scale_bits: 33,
            levels: 2 * model.layers.len() + 2 + nl,
            special_bits: 55,
            allow_insecure: true,
        };
        crate::ckks::set_limb_parallelism(limb_threads);
        let sess = crate::he_infer::PrivateInferenceSession::new(&model, params, 7)?;
        let input = sess.encrypt_input(&model, x)?;
        let out = sess.infer_parallel(&input, threads)?;
        sess.decrypt_logits(&model, &out)
    } else {
        model.forward(x)?
    };
    let arg = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!(
        "mode={} nl={nl} predicted_class={arg} latency={:?}\nlogits={logits:?}",
        if encrypted { "encrypted" } else { "plaintext" },
        t0.elapsed()
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let workers: usize = arg_value(args, "--workers").unwrap_or_else(|| "2".into()).parse()?;
    let requests: usize = arg_value(args, "--requests").unwrap_or_else(|| "64".into()).parse()?;
    let tier = arg_value(args, "--tier").unwrap_or_else(|| "plaintext".into());
    let threads: usize = arg_value(args, "--threads").unwrap_or_else(|| "1".into()).parse()?;
    let limb_threads: usize =
        arg_value(args, "--limb-threads").unwrap_or_else(|| "1".into()).parse()?;
    // limb fan-out composes multiplicatively with the plan-executor pool
    // and the worker pool — keep the product near the core count
    crate::ckks::set_limb_parallelism(limb_threads);
    let cost = OpCostModel::reference();
    let metrics = std::sync::Arc::new(crate::coordinator::Metrics::default());
    let (router, executor): (
        crate::coordinator::Router,
        std::sync::Arc<dyn crate::coordinator::InferenceExecutor>,
    ) = match tier.as_str() {
        "plaintext" => {
            let (router, exec) = crate::coordinator::from_artifacts(Path::new("artifacts"), &cost)?;
            (router, std::sync::Arc::new(exec))
        }
        "he" => {
            let (router, mut exec) =
                crate::coordinator::he_from_artifacts(Path::new("artifacts"), &cost, threads)?;
            exec.set_metrics(metrics.clone());
            (router, std::sync::Arc::new(exec))
        }
        other => anyhow::bail!("unknown tier {other} (expected plaintext|he)"),
    };
    println!("variants:");
    for v in router.variants() {
        println!(
            "  {} nl={} acc={:.3} predicted-HE-latency={:.0}s",
            v.name, v.nl, v.accuracy, v.latency_s
        );
    }
    let coord = crate::coordinator::Coordinator::start_with_metrics(
        router,
        executor,
        metrics,
        workers,
        8,
        std::time::Duration::from_millis(2),
    );
    let ex = crate::util::tensorio::TensorFile::load(Path::new("artifacts/example_input.lgt"))?;
    let x = ex.get("x")?.data.clone();
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..requests {
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        coord.submit(crate::coordinator::Request {
            clip: x.clone(),
            latency_budget_s: if i % 3 == 0 { Some(1000.0) } else { None },
            resp: tx,
        })?;
        rxs.push(rx);
    }
    for rx in rxs {
        let _ = rx.recv()?;
    }
    let wall = t0.elapsed();
    println!("{}", coord.metrics.summary());
    println!(
        "{requests} requests in {wall:?} → {:.1} req/s ({tier} tier, {workers} workers, \
         {threads} plan-exec threads)",
        requests as f64 / wall.as_secs_f64()
    );
    coord.shutdown();
    Ok(())
}
