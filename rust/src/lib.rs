//! LinGCN: Structural Linearized Graph Convolutional Network for
//! Homomorphically Encrypted Inference (NeurIPS 2023) — full-system
//! reproduction.
//!
//! Three-layer architecture (see `README.md` for the map and `DESIGN.md`
//! for the per-subsystem sections S1–S18):
//! - **L3 (this crate)**: CKKS leveled-HE substrate, AMA-packed encrypted
//!   STGCN inference engine, level planner, serving coordinator, and the
//!   `wire` client/server privacy boundary — including its TCP serving
//!   tier (`wire::net`: streamed ciphertext upload, per-tenant admission,
//!   `serve --tier he-wire --listen` / `infer-remote`).
//! - **L2 (python/compile)**: JAX STGCN model + LinGCN training pipeline
//!   (structural linearization, polynomial replacement, distillation),
//!   AOT-lowered to HLO text artifacts.
//! - **L1 (python/compile/kernels)**: Pallas kernels for the compute
//!   hot-spots, validated against pure-jnp oracles.
//!
//! # Feature flags
//!
//! * **`pjrt`** (default off): back [`runtime::PjrtModel`] with the XLA
//!   CPU PJRT client, compiling the AOT HLO artifact
//!   (`artifacts/model.hlo.txt`) for the plaintext serving tier. Requires
//!   an `xla` crate in the build environment, which the offline default
//!   toolchain does not provide. With the feature off (the default),
//!   `runtime::PjrtModel` is a native executor backed by
//!   [`stgcn::StgcnModel`] with the identical API and numerics, so the
//!   coordinator, examples and benches build and run everywhere.

pub mod ckks;
pub mod cli;
pub mod graph;
pub mod stgcn;
pub mod ama;
pub mod he_infer;
pub mod linearize;
pub mod costmodel;
pub mod coordinator;
pub mod runtime;
pub mod util;
pub mod wire;
