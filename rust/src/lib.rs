//! LinGCN: Structural Linearized Graph Convolutional Network for
//! Homomorphically Encrypted Inference (NeurIPS 2023) — full-system
//! reproduction.
//!
//! Three-layer architecture:
//! - **L3 (this crate)**: CKKS leveled-HE substrate, AMA-packed encrypted
//!   STGCN inference engine, level planner, serving coordinator.
//! - **L2 (python/compile)**: JAX STGCN model + LinGCN training pipeline
//!   (structural linearization, polynomial replacement, distillation),
//!   AOT-lowered to HLO text artifacts.
//! - **L1 (python/compile/kernels)**: Pallas kernels for the compute
//!   hot-spots, validated against pure-jnp oracles.

pub mod ckks;
pub mod graph;
pub mod stgcn;
pub mod ama;
pub mod he_infer;
pub mod linearize;
pub mod costmodel;
pub mod coordinator;
pub mod runtime;
pub mod util;
