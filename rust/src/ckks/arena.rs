//! Thread-local scratch-buffer arena for RNS limb sets (DESIGN.md
//! §Perf-6).
//!
//! Every hot evaluator op used to allocate fresh multi-MiB buffers —
//! `ks_digit` one `(nq+1)`-limb polynomial per digit, `key_switch_coeff`
//! two accumulators, `mod_down` its output, `automorphism_ntt` and
//! `RnsPoly::mul` a full clone they then overwrite. At paper-scale N a
//! single limb is 256 KiB, so one rotation churned tens of MiB through
//! the allocator per call. This arena recycles those buffers per thread,
//! keyed by `(ring degree, limb count)`.
//!
//! Contract: [`take_limbs`] returns **dirty** buffers — the contents are
//! whatever the previous user left; callers must overwrite every word
//! (all call sites do: permutations, pointwise products, and spreads
//! write the full range). [`take_acc`] returns **zeroed** `u128`
//! accumulators, because accumulation reads before writing. Buffers that
//! escape (e.g. a `mod_down` output that becomes part of a ciphertext)
//! are simply never recycled — the arena only sees what callers
//! explicitly hand back, so there is no ownership tracking to get wrong.
//!
//! Being thread-local, the arena needs no locks and interacts safely
//! with both the persistent pool and scoped spawns. Caps: at most
//! [`MAX_PER_KEY`] buffers per shape and [`MAX_THREAD_BYTES`] total per
//! thread; excess buffers drop to the allocator as before.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};

/// Ablation toggle (bench mode `--kernels`): `true` (default) recycles
/// scratch buffers; `false` makes every take a fresh allocation (the
/// pre-campaign behavior). Values produced are bit-identical either way.
static ARENA_ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable/disable scratch-buffer recycling (the ablation baseline
/// allocates fresh, as the pre-campaign code did).
pub fn set_arena_enabled(enabled: bool) {
    ARENA_ENABLED.store(enabled, Ordering::Relaxed);
}

/// Whether scratch buffers are currently recycled.
pub fn arena_enabled() -> bool {
    ARENA_ENABLED.load(Ordering::Relaxed)
}

/// Max recycled buffers kept per `(n, limb_count)` shape.
const MAX_PER_KEY: usize = 4;

/// Max bytes of recycled buffers kept per thread (paper-scale key switch
/// keeps a handful of shapes live; beyond this, buffers drop to the
/// allocator instead of accumulating).
const MAX_THREAD_BYTES: usize = 192 << 20;

#[derive(Default)]
struct ThreadArena {
    limbs: HashMap<(usize, usize), Vec<Vec<Vec<u64>>>>,
    accs: HashMap<(usize, usize), Vec<Vec<Vec<u128>>>>,
    bytes: usize,
}

thread_local! {
    static ARENA: RefCell<ThreadArena> = RefCell::new(ThreadArena::default());
}

fn limb_bytes(n: usize, count: usize) -> usize {
    n * count * std::mem::size_of::<u64>()
}

fn acc_bytes(n: usize, count: usize) -> usize {
    n * count * std::mem::size_of::<u128>()
}

/// Take a `count`-limb buffer set, each limb `n` words, **dirty** — the
/// caller must overwrite every word before reading any.
pub fn take_limbs(n: usize, count: usize) -> Vec<Vec<u64>> {
    if arena_enabled() {
        let hit = ARENA.with(|a| {
            let mut a = a.borrow_mut();
            let buf = a.limbs.get_mut(&(n, count)).and_then(|v| v.pop());
            if buf.is_some() {
                a.bytes -= limb_bytes(n, count);
            }
            buf
        });
        if let Some(buf) = hit {
            debug_assert!(buf.len() == count && buf.iter().all(|l| l.len() == n));
            return buf;
        }
    }
    vec![vec![0u64; n]; count]
}

/// Return a limb buffer set to the current thread's arena (no-op when
/// disabled, ragged, or over the caps).
pub fn recycle_limbs(buf: Vec<Vec<u64>>) {
    if !arena_enabled() || buf.is_empty() {
        return;
    }
    let (n, count) = (buf[0].len(), buf.len());
    if buf.iter().any(|l| l.len() != n) {
        return;
    }
    ARENA.with(|a| {
        let mut a = a.borrow_mut();
        let bytes = limb_bytes(n, count);
        if a.bytes + bytes > MAX_THREAD_BYTES {
            return;
        }
        let slot = a.limbs.entry((n, count)).or_default();
        if slot.len() < MAX_PER_KEY {
            slot.push(buf);
            a.bytes += bytes;
        }
    });
}

/// Take a `count`-limb set of **zeroed** 128-bit accumulators (the fused
/// key-switch inner product reads before writing, so recycled buffers
/// are re-zeroed here).
pub fn take_acc(n: usize, count: usize) -> Vec<Vec<u128>> {
    if arena_enabled() {
        let hit = ARENA.with(|a| {
            let mut a = a.borrow_mut();
            let buf = a.accs.get_mut(&(n, count)).and_then(|v| v.pop());
            if buf.is_some() {
                a.bytes -= acc_bytes(n, count);
            }
            buf
        });
        if let Some(mut buf) = hit {
            debug_assert!(buf.len() == count && buf.iter().all(|l| l.len() == n));
            for limb in &mut buf {
                limb.fill(0);
            }
            return buf;
        }
    }
    vec![vec![0u128; n]; count]
}

/// Return an accumulator set to the current thread's arena.
pub fn recycle_acc(buf: Vec<Vec<u128>>) {
    if !arena_enabled() || buf.is_empty() {
        return;
    }
    let (n, count) = (buf[0].len(), buf.len());
    if buf.iter().any(|l| l.len() != n) {
        return;
    }
    ARENA.with(|a| {
        let mut a = a.borrow_mut();
        let bytes = acc_bytes(n, count);
        if a.bytes + bytes > MAX_THREAD_BYTES {
            return;
        }
        let slot = a.accs.entry((n, count)).or_default();
        if slot.len() < MAX_PER_KEY {
            slot.push(buf);
            a.bytes += bytes;
        }
    });
}

/// Buffers currently pooled by this thread (tests/diagnostics).
pub fn pooled_buffers() -> usize {
    ARENA.with(|a| {
        let a = a.borrow();
        a.limbs.values().map(Vec::len).sum::<usize>() + a.accs.values().map(Vec::len).sum::<usize>()
    })
}

/// Bytes currently pooled by this thread (tests/diagnostics).
pub fn pooled_bytes() -> usize {
    ARENA.with(|a| a.borrow().bytes)
}

/// Drop every buffer pooled by this thread (tests).
pub fn clear() {
    ARENA.with(|a| {
        let mut a = a.borrow_mut();
        a.limbs.clear();
        a.accs.clear();
        a.bytes = 0;
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_limbs_roundtrip_and_reuse() {
        clear();
        set_arena_enabled(true);
        let mut b = take_limbs(64, 3);
        assert_eq!(b.len(), 3);
        b[1][7] = 0xdead;
        recycle_limbs(b);
        assert_eq!(pooled_buffers(), 1);
        let b2 = take_limbs(64, 3);
        assert_eq!(pooled_buffers(), 0, "same-shape take must reuse");
        // dirty contract: the recycled buffer keeps its old contents
        assert_eq!(b2[1][7], 0xdead);
        // different shape misses the pool
        recycle_limbs(b2);
        let other = take_limbs(64, 4);
        assert_eq!(pooled_buffers(), 1);
        recycle_limbs(other);
        clear();
    }

    #[test]
    fn test_acc_rezeroed_on_reuse() {
        clear();
        set_arena_enabled(true);
        let mut acc = take_acc(32, 2);
        acc[0][5] = 999;
        recycle_acc(acc);
        let acc2 = take_acc(32, 2);
        assert!(acc2.iter().all(|l| l.iter().all(|&v| v == 0)));
        recycle_acc(acc2);
        clear();
    }

    #[test]
    fn test_per_key_cap() {
        clear();
        set_arena_enabled(true);
        for _ in 0..(MAX_PER_KEY + 3) {
            recycle_limbs(vec![vec![0u64; 16]; 2]);
        }
        assert_eq!(pooled_buffers(), MAX_PER_KEY);
        clear();
    }

    #[test]
    fn test_disabled_allocates_fresh() {
        clear();
        set_arena_enabled(false);
        recycle_limbs(vec![vec![7u64; 16]; 2]);
        assert_eq!(pooled_buffers(), 0, "disabled arena keeps nothing");
        let b = take_limbs(16, 2);
        assert!(b.iter().all(|l| l.iter().all(|&v| v == 0)));
        set_arena_enabled(true);
        clear();
    }

    #[test]
    fn test_bytes_accounting() {
        clear();
        set_arena_enabled(true);
        recycle_limbs(vec![vec![0u64; 128]; 4]);
        assert_eq!(pooled_bytes(), 128 * 4 * 8);
        let _ = take_limbs(128, 4);
        assert_eq!(pooled_bytes(), 0);
        clear();
    }
}
