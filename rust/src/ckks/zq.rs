//! Modular arithmetic over word-sized prime fields `Z_q` (q < 2^62).
//!
//! Every CKKS polynomial coefficient lives in one of these fields (one per
//! RNS prime). The hot path is `mul_mod`, which gets a Shoup-precomputed
//! variant (`ShoupMul`) used by the NTT butterflies and pointwise products.

/// `(a + b) mod q`, assuming `a, b < q < 2^63`.
#[inline(always)]
pub fn add_mod(a: u64, b: u64, q: u64) -> u64 {
    let s = a + b;
    if s >= q {
        s - q
    } else {
        s
    }
}

/// `(a - b) mod q`, assuming `a, b < q`.
#[inline(always)]
pub fn sub_mod(a: u64, b: u64, q: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + q - b
    }
}

/// `(a * b) mod q` via 128-bit widening.
#[inline(always)]
pub fn mul_mod(a: u64, b: u64, q: u64) -> u64 {
    ((a as u128 * b as u128) % q as u128) as u64
}

/// `(-a) mod q`.
#[inline(always)]
pub fn neg_mod(a: u64, q: u64) -> u64 {
    if a == 0 {
        0
    } else {
        q - a
    }
}

/// `a^e mod q` by square-and-multiply.
pub fn pow_mod(mut a: u64, mut e: u64, q: u64) -> u64 {
    let mut r: u64 = 1;
    a %= q;
    while e > 0 {
        if e & 1 == 1 {
            r = mul_mod(r, a, q);
        }
        a = mul_mod(a, a, q);
        e >>= 1;
    }
    r
}

/// Modular inverse of `a` modulo prime `q` (Fermat). Panics if `a == 0`.
pub fn inv_mod(a: u64, q: u64) -> u64 {
    assert!(a % q != 0, "inverse of zero mod {q}");
    pow_mod(a, q - 2, q)
}

/// Shoup-precomputed multiplication by a fixed constant `w < q`:
/// one 64x64->128 mul and one subtraction instead of a 128-bit division.
/// This is the classic Harvey/Shoup trick that dominates NTT performance.
#[derive(Clone, Copy, Debug)]
pub struct ShoupMul {
    pub w: u64,
    /// floor(w * 2^64 / q)
    pub w_shoup: u64,
}

impl ShoupMul {
    #[inline]
    pub fn new(w: u64, q: u64) -> Self {
        debug_assert!(w < q);
        let w_shoup = ((w as u128) << 64) / q as u128;
        ShoupMul {
            w,
            w_shoup: w_shoup as u64,
        }
    }

    /// `(a * w) mod q` in [0, 2q); caller may keep values lazy.
    #[inline(always)]
    pub fn mul_lazy(&self, a: u64, q: u64) -> u64 {
        let hi = ((self.w_shoup as u128 * a as u128) >> 64) as u64;
        self.w
            .wrapping_mul(a)
            .wrapping_sub(hi.wrapping_mul(q))
    }

    /// `(a * w) mod q`, fully reduced.
    #[inline(always)]
    pub fn mul(&self, a: u64, q: u64) -> u64 {
        let r = self.mul_lazy(a, q);
        if r >= q {
            r - q
        } else {
            r
        }
    }
}

/// Deterministic Miller-Rabin for u64 (the standard 12-witness set is
/// sufficient for all 64-bit integers).
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n % p == 0 {
            return n == p;
        }
    }
    let mut d = n - 1;
    let mut r = 0u32;
    while d & 1 == 0 {
        d >>= 1;
        r += 1;
    }
    'witness: for a in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..r - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Generate `count` distinct NTT-friendly primes (`p ≡ 1 mod 2n`) of
/// roughly `bits` bits, scanning downward from `2^bits`. `n` is the ring
/// degree, so the negacyclic NTT of size `n` exists mod each returned prime.
pub fn gen_ntt_primes(bits: u32, n: usize, count: usize, exclude: &[u64]) -> Vec<u64> {
    assert!(bits >= 20 && bits <= 61, "prime bits {bits} out of range");
    let step = 2 * n as u64;
    let mut primes = Vec::with_capacity(count);
    // start at the largest candidate ≡ 1 mod 2n below 2^bits
    let top = 1u64 << bits;
    let mut cand = top - (top % step) + 1;
    while cand >= top {
        cand -= step;
    }
    while primes.len() < count {
        assert!(cand > (1u64 << (bits - 1)), "ran out of {bits}-bit NTT primes");
        if is_prime(cand) && !exclude.contains(&cand) && !primes.contains(&cand) {
            primes.push(cand);
        }
        cand -= step;
    }
    primes
}

/// Find a primitive 2n-th root of unity mod prime `q` (requires
/// `q ≡ 1 mod 2n`). Returns `psi` with `psi^n ≡ -1 (mod q)`.
pub fn primitive_2nth_root(n: usize, q: u64) -> u64 {
    let order = 2 * n as u64;
    assert_eq!((q - 1) % order, 0, "q-1 not divisible by 2n");
    let cofactor = (q - 1) / order;
    // try small candidates deterministically
    for x in 2u64.. {
        let psi = pow_mod(x, cofactor, q);
        // psi has order dividing 2n; primitive iff psi^n == -1
        if pow_mod(psi, n as u64, q) == q - 1 {
            return psi;
        }
        if x > 10_000 {
            panic!("no primitive 2n-th root found mod {q}");
        }
    }
    unreachable!()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_add_sub_neg() {
        let q = 97;
        assert_eq!(add_mod(90, 10, q), 3);
        assert_eq!(sub_mod(3, 10, q), 90);
        assert_eq!(neg_mod(0, q), 0);
        assert_eq!(neg_mod(5, q), 92);
    }

    #[test]
    fn test_mul_pow_inv() {
        let q = (1u64 << 61) - 1; // Mersenne prime
        let a = 123456789012345678 % q;
        let b = 987654321098765432 % q;
        let ab = mul_mod(a, b, q);
        assert_eq!(mul_mod(ab, inv_mod(b, q), q), a);
        assert_eq!(pow_mod(a, q - 1, q), 1); // Fermat
    }

    #[test]
    fn test_shoup_matches_mul_mod() {
        let q = gen_ntt_primes(50, 1024, 1, &[])[0];
        let w = 0x1234_5678_9abc % q;
        let sm = ShoupMul::new(w, q);
        for a in [0u64, 1, q - 1, q / 2, 42, 0xdead_beef % q] {
            assert_eq!(sm.mul(a, q), mul_mod(a, w, q), "a={a}");
        }
    }

    #[test]
    fn test_is_prime_smoke() {
        assert!(is_prime(2));
        assert!(is_prime(1_000_000_007));
        assert!(!is_prime(1));
        assert!(!is_prime(561)); // Carmichael
        assert!(is_prime((1u64 << 61) - 1));
    }

    #[test]
    fn test_gen_ntt_primes_properties() {
        let n = 4096;
        let ps = gen_ntt_primes(45, n, 4, &[]);
        assert_eq!(ps.len(), 4);
        for &p in &ps {
            assert!(is_prime(p));
            assert_eq!(p % (2 * n as u64), 1);
            assert!(p < (1u64 << 45) && p > (1u64 << 44));
        }
        // distinct
        let mut sorted = ps.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
        // exclusion respected
        let more = gen_ntt_primes(45, n, 2, &ps);
        assert!(more.iter().all(|p| !ps.contains(p)));
    }

    #[test]
    fn test_primitive_root() {
        for n in [8usize, 1024] {
            let q = gen_ntt_primes(40, n, 1, &[])[0];
            let psi = primitive_2nth_root(n, q);
            assert_eq!(pow_mod(psi, n as u64, q), q - 1);
            assert_eq!(pow_mod(psi, 2 * n as u64, q), 1);
        }
    }
}

/// Barrett reduction context for a fixed modulus `q < 2^62`: reduces any
/// 128-bit value mod q with two 64×64 multiplies instead of a (software)
/// 128-bit division — the DESIGN.md §Perf-1 optimization that removes
/// `__umodti3` from every pointwise product and key-switch digit.
#[derive(Clone, Copy, Debug)]
pub struct Barrett {
    pub q: u64,
    /// floor(2^128 / q), as (hi, lo) 64-bit words.
    ratio_hi: u64,
    ratio_lo: u64,
}

impl Barrett {
    pub fn new(q: u64) -> Self {
        debug_assert!(q > 1);
        // floor(2^128 / q) = floor((2^128 - 1) / q) unless q | 2^128
        let max = u128::MAX;
        let mut ratio = max / q as u128;
        if max % q as u128 == (q - 1) as u128 {
            ratio += 1;
        }
        Barrett {
            q,
            ratio_hi: (ratio >> 64) as u64,
            ratio_lo: ratio as u64,
        }
    }

    /// Reduce a 128-bit value mod q (SEAL-style two-round Barrett).
    #[inline(always)]
    pub fn reduce_u128(&self, z: u128) -> u64 {
        let z_lo = z as u64;
        let z_hi = (z >> 64) as u64;
        // round 1: carry = hi64(z_lo * ratio_lo)
        let carry = ((z_lo as u128 * self.ratio_lo as u128) >> 64) as u64;
        let tmp2 = z_lo as u128 * self.ratio_hi as u128;
        let tmp1 = (tmp2 as u64).wrapping_add(carry);
        let tmp3 = ((tmp2 >> 64) as u64).wrapping_add((tmp1 < carry) as u64);
        // round 2
        let tmp2b = z_hi as u128 * self.ratio_lo as u128;
        let tmp1b = tmp1.wrapping_add(tmp2b as u64);
        let carry2 = ((tmp2b >> 64) as u64).wrapping_add((tmp1b < tmp2b as u64) as u64);
        let quot = z_hi
            .wrapping_mul(self.ratio_hi)
            .wrapping_add(tmp3)
            .wrapping_add(carry2);
        let mut r = z_lo.wrapping_sub(quot.wrapping_mul(self.q));
        if r >= self.q {
            r -= self.q;
        }
        r
    }

    /// `(a*b) mod q`.
    #[inline(always)]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        self.reduce_u128(a as u128 * b as u128)
    }

    /// Reduce a 64-bit value mod q.
    #[inline(always)]
    pub fn reduce_u64(&self, a: u64) -> u64 {
        self.reduce_u128(a as u128)
    }
}

#[cfg(test)]
mod barrett_tests {
    use super::*;

    #[test]
    fn test_barrett_matches_division() {
        for &q in &[
            3u64,
            97,
            (1u64 << 33) - 9,
            gen_ntt_primes(50, 1024, 1, &[])[0],
            gen_ntt_primes(60, 1024, 1, &[])[0],
        ] {
            let b = Barrett::new(q);
            let samples: Vec<u128> = vec![
                0,
                1,
                q as u128 - 1,
                q as u128,
                q as u128 + 1,
                u64::MAX as u128,
                (q as u128) * (q as u128) - 1,
                u128::MAX / 3,
                0xdead_beef_cafe_1234_5678_9abc_def0_1111u128 % ((q as u128) * (q as u128)),
            ];
            for z in samples {
                assert_eq!(b.reduce_u128(z), (z % q as u128) as u64, "q={q} z={z}");
            }
            // randomized products
            let mut x = 0x12345u64;
            for _ in 0..200 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let a = x % q;
                let c = x.rotate_left(17) % q;
                assert_eq!(b.mul(a, c), mul_mod(a, c, q));
            }
        }
    }
}
