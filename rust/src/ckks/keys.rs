//! Key material: secret key, public key, and key-switching keys
//! (relinearization + Galois) in the hybrid (special-prime) variant
//! (DESIGN.md S5).
//!
//! A key-switching key from key `t` to secret `s` consists of one
//! RLWE pair per RNS digit: `ksk_i = (b_i, a_i)` over the extended basis
//! `Q·P` with `b_i = -a_i·s + e_i + P·g_i·t`, where `g_i` is the CRT basis
//! element of `q_i` in `Q` (so `g_i ≡ δ_ij (mod q_j)` — which makes the same
//! key valid at *every* level, the property the level-reduction story of the
//! paper depends on).

use super::params::CkksContext;
use super::poly::RnsPoly;
use super::zq;
use crate::util::Rng;
use std::collections::HashMap;

/// Secret key: ternary polynomial, cached in NTT form over the full
/// `Q ∪ {P}` basis so any level's limbs can be sliced out.
#[derive(Clone, Debug, PartialEq)]
pub struct SecretKey {
    /// NTT form, nq = all Q primes, has_special = true.
    pub s: RnsPoly,
}

/// Public encryption key `(b, a)` with `b = -a·s + e` over the full Q basis.
#[derive(Clone, Debug, PartialEq)]
pub struct PublicKey {
    pub b: RnsPoly,
    pub a: RnsPoly,
}

/// One digit of a key-switching key.
#[derive(Clone, Debug, PartialEq)]
pub struct KskDigit {
    pub b: RnsPoly,
    pub a: RnsPoly,
}

/// Key-switching key: one digit pair per RNS prime of Q.
#[derive(Clone, Debug, PartialEq)]
pub struct KeySwitchKey {
    pub digits: Vec<KskDigit>,
}

/// All evaluation keys an `Evaluator` needs. Deliberately excludes the
/// secret key: this is the exact key material that crosses the wire to
/// the server (`wire::EvalKeySet` serializes it).
#[derive(Clone, Debug, PartialEq)]
pub struct EvalKeys {
    pub relin: KeySwitchKey,
    /// Galois element -> key (for rotations and conjugation).
    pub galois: HashMap<usize, KeySwitchKey>,
}

impl RnsPoly {
    /// Slice the first `nq` Q limbs plus (optionally) the special limb.
    /// `self` must carry a special limb if `with_special` is requested.
    pub fn subset(&self, nq: usize, with_special: bool) -> RnsPoly {
        assert!(nq <= self.nq);
        assert!(!with_special || self.has_special);
        let mut limbs: Vec<Vec<u64>> = self.limbs[..nq].to_vec();
        if with_special {
            limbs.push(self.limbs[self.nq].clone());
        }
        RnsPoly {
            limbs,
            nq,
            has_special: with_special,
            is_ntt: self.is_ntt,
        }
    }
}

/// Generate a ternary secret key.
pub fn keygen_secret(ctx: &CkksContext, rng: &mut Rng) -> SecretKey {
    let k = ctx.moduli.len();
    let mut s = RnsPoly::sample_ternary(ctx, k, true, rng);
    s.ntt_forward(ctx);
    SecretKey { s }
}

/// Generate the public key from the secret key (full Q basis, no special).
pub fn keygen_public(ctx: &CkksContext, sk: &SecretKey, rng: &mut Rng) -> PublicKey {
    let k = ctx.moduli.len();
    let mut a = RnsPoly::sample_uniform(ctx, k, false, rng);
    a.is_ntt = true; // uniform is uniform in either domain
    let mut e = RnsPoly::sample_gaussian(ctx, k, false, rng);
    e.ntt_forward(ctx);
    // b = -a*s + e
    let s_q = sk.s.subset(k, false);
    let mut b = a.mul(ctx, &s_q);
    b.neg_assign(ctx);
    b.add_assign(ctx, &e);
    PublicKey { b, a }
}

/// Generate a key-switching key from target key `t` (NTT form over Q∪{P})
/// to the secret `s`.
pub fn keygen_kswitch(
    ctx: &CkksContext,
    sk: &SecretKey,
    t: &RnsPoly,
    rng: &mut Rng,
) -> KeySwitchKey {
    let k = ctx.moduli.len();
    assert!(t.is_ntt && t.nq == k && t.has_special);
    let mut digits = Vec::with_capacity(k);
    for i in 0..k {
        let mut a = RnsPoly::sample_uniform(ctx, k, true, rng);
        a.is_ntt = true;
        let mut e = RnsPoly::sample_gaussian(ctx, k, true, rng);
        e.ntt_forward(ctx);
        // b = -a*s + e  over Q∪{P}
        let mut b = a.mul(ctx, &sk.s);
        b.neg_assign(ctx);
        b.add_assign(ctx, &e);
        // += P * g_i * t : only limb i of the Q part gets (P mod q_i) * t_i
        let q_i = ctx.moduli[i];
        let p_mod_qi = ctx.special % q_i;
        for (slot, &tv) in b.limbs[i]
            .iter_mut()
            .zip(t.limbs[i].iter())
            .map(|(s, t)| (s, t))
        {
            *slot = zq::add_mod(*slot, zq::mul_mod(p_mod_qi, tv, q_i), q_i);
        }
        digits.push(KskDigit { b, a });
    }
    KeySwitchKey { digits }
}

/// Relinearization key: key-switch from s² to s.
pub fn keygen_relin(ctx: &CkksContext, sk: &SecretKey, rng: &mut Rng) -> KeySwitchKey {
    let s2 = sk.s.mul(ctx, &sk.s);
    keygen_kswitch(ctx, sk, &s2, rng)
}

/// Galois key for element `g`: key-switch from τ_g(s) to s.
pub fn keygen_galois(
    ctx: &CkksContext,
    sk: &SecretKey,
    g: usize,
    rng: &mut Rng,
) -> KeySwitchKey {
    let mut s_coeff = sk.s.clone();
    s_coeff.ntt_inverse(ctx);
    let mut ts = s_coeff.automorphism(ctx, g);
    ts.ntt_forward(ctx);
    keygen_kswitch(ctx, sk, &ts, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::params::CkksParams;

    #[test]
    fn test_public_key_is_rlwe_sample() {
        // b + a*s must be small (the error) — check it decodes to ~0.
        let mut p = CkksParams::toy(2);
        p.n = 1 << 7;
        let ctx = p.build().unwrap();
        let mut rng = crate::util::Rng::seed_from_u64(42);
        let sk = keygen_secret(&ctx, &mut rng);
        let pk = keygen_public(&ctx, &sk, &mut rng);
        let k = ctx.moduli.len();
        let s_q = sk.s.subset(k, false);
        let mut t = pk.a.mul(&ctx, &s_q);
        t.add_assign(&ctx, &pk.b);
        t.ntt_inverse(&ctx);
        let coeffs = t.to_signed_coeffs_i128(&ctx);
        for c in coeffs {
            assert!(c.unsigned_abs() < 64, "error coefficient too large: {c}");
        }
    }

    #[test]
    fn test_subset_shapes() {
        let mut p = CkksParams::toy(3);
        p.n = 1 << 6;
        let ctx = p.build().unwrap();
        let mut rng = crate::util::Rng::seed_from_u64(7);
        let sk = keygen_secret(&ctx, &mut rng);
        let sub = sk.s.subset(2, false);
        assert_eq!(sub.nq, 2);
        assert!(!sub.has_special);
        assert_eq!(sub.limbs.len(), 2);
        let sub2 = sk.s.subset(2, true);
        assert_eq!(sub2.limbs.len(), 3);
        // special limb must be the original's special limb
        assert_eq!(sub2.limbs[2], sk.s.limbs[sk.s.nq]);
    }
}
