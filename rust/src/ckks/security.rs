//! Security estimation per the Homomorphic Encryption Standard tables
//! (Albrecht et al., "Security of Homomorphic Encryption", 2017/2018):
//! maximum log2(Q·P) for 128-bit classical security with ternary secrets.
//!
//! The paper's Table 6 selects N by exactly this rule — these bounds let
//! the level planner (`he_infer::level_plan`) reproduce that table. See
//! DESIGN.md S3 for the accounting policy (Q vs Q·P).

/// (N, max log2 QP) rows for 128-bit classical security.
pub const MAX_LOG_QP_128: &[(usize, u32)] = &[
    (1024, 27),
    (2048, 54),
    (4096, 109),
    (8192, 218),
    (16384, 438),
    (32768, 881),
    (65536, 1772),
];

/// Maximum total modulus bits at 128-bit security for ring degree `n`
/// (0 if `n` below the table).
pub fn max_log_qp_128(n: usize) -> u32 {
    MAX_LOG_QP_128
        .iter()
        .find(|&&(nn, _)| nn == n)
        .map(|&(_, b)| b)
        .unwrap_or(0)
}

/// Does (N, logQP) meet 128-bit security?
pub fn is_secure_128(n: usize, log_qp: u32) -> bool {
    log_qp <= max_log_qp_128(n)
}

/// Smallest power-of-two ring degree giving 128-bit security for `log_qp`
/// total modulus bits. Returns `None` if even N=2^16 is insufficient.
pub fn min_secure_n(log_qp: u32) -> Option<usize> {
    MAX_LOG_QP_128
        .iter()
        .find(|&&(_, b)| b >= log_qp)
        .map(|&(n, _)| n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_table_monotone() {
        for w in MAX_LOG_QP_128.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 < w[1].1);
        }
    }

    #[test]
    fn test_paper_table6_n_selection() {
        // Table 6 reports Q excluding the key-switching prime; the paper's
        // N choice matches min_secure_n on Q alone (SEAL counts the special
        // prime separately) — verify all rows.
        let rows: &[(u32, usize)] = &[
            (509, 32768),
            (476, 32768),
            (443, 32768),
            (410, 16384),
            (377, 16384),
            (344, 16384),
            (932, 65536),
            (899, 65536),
            (767, 32768),
            (701, 32768),
            (668, 32768),
            (635, 32768),
            (602, 32768),
            (569, 32768),
        ];
        for &(q, n) in rows {
            assert_eq!(min_secure_n(q), Some(n), "Q={q}");
        }
    }

    #[test]
    fn test_insecure_detection() {
        assert!(!is_secure_128(2048, 100));
        assert!(is_secure_128(32768, 881));
        assert!(!is_secure_128(32768, 882));
        assert_eq!(min_secure_n(3000), None);
    }
}
