//! Encryption and decryption.
//!
//! Ciphertexts are pairs `(c0, c1)` with `c0 + c1·s ≈ Δ·m + e (mod Q_ℓ)`.
//! Both components are stored in NTT form; the `level` is `nq - 1` (the
//! number of Rescale operations still available).

use super::encoding::Plaintext;
use super::keys::{PublicKey, SecretKey};
use super::params::CkksContext;
use super::poly::RnsPoly;
use crate::util::Rng;

/// A CKKS ciphertext.
#[derive(Clone, Debug, PartialEq)]
pub struct Ciphertext {
    pub c0: RnsPoly,
    pub c1: RnsPoly,
    /// Current encoding scale (drifts slightly away from Δ across rescales).
    pub scale: f64,
}

impl Ciphertext {
    /// Remaining multiplicative level (number of rescales available).
    pub fn level(&self) -> usize {
        self.c0.nq - 1
    }

    pub fn nq(&self) -> usize {
        self.c0.nq
    }
}

/// Public-key encryption of an encoded plaintext.
pub fn encrypt(
    ctx: &CkksContext,
    pk: &PublicKey,
    pt: &Plaintext,
    rng: &mut Rng,
) -> Ciphertext {
    let nq = pt.poly.nq;
    assert!(pt.poly.is_ntt, "plaintext must be in NTT form");
    let mut v = RnsPoly::sample_ternary(ctx, nq, false, rng);
    v.ntt_forward(ctx);
    let mut e0 = RnsPoly::sample_gaussian(ctx, nq, false, rng);
    e0.ntt_forward(ctx);
    let mut e1 = RnsPoly::sample_gaussian(ctx, nq, false, rng);
    e1.ntt_forward(ctx);

    let pk_b = pk.b.subset(nq, false);
    let pk_a = pk.a.subset(nq, false);
    let mut c0 = v.mul(ctx, &pk_b);
    c0.add_assign(ctx, &e0);
    c0.add_assign(ctx, &pt.poly);
    let mut c1 = v.mul(ctx, &pk_a);
    c1.add_assign(ctx, &e1);

    Ciphertext {
        c0,
        c1,
        scale: pt.scale,
    }
}

/// Decryption: `m ≈ c0 + c1·s`.
pub fn decrypt(ctx: &CkksContext, sk: &SecretKey, ct: &Ciphertext) -> Plaintext {
    let nq = ct.c0.nq;
    let s_q = sk.s.subset(nq, false);
    let mut m = ct.c1.mul(ctx, &s_q);
    m.add_assign(ctx, &ct.c0);
    Plaintext {
        poly: m,
        scale: ct.scale,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Encoder;
    use crate::ckks::keys::{keygen_public, keygen_secret};
    use crate::ckks::params::CkksParams;

    #[test]
    fn test_encrypt_decrypt_roundtrip() {
        let mut p = CkksParams::toy(3);
        p.n = 1 << 9;
        let ctx = p.build().unwrap();
        let enc = Encoder::new(ctx.n);
        let mut rng = crate::util::Rng::seed_from_u64(11);
        let sk = keygen_secret(&ctx, &mut rng);
        let pk = keygen_public(&ctx, &sk, &mut rng);

        let half = ctx.slots();
        let vals: Vec<f64> = (0..half).map(|i| ((i % 17) as f64 - 8.0) / 8.0).collect();
        let pt = enc.encode(&ctx, &vals, ctx.scale, 4);
        let ct = encrypt(&ctx, &pk, &pt, &mut rng);
        assert_eq!(ct.level(), 3);
        let dec = decrypt(&ctx, &sk, &ct);
        let back = enc.decode(&ctx, &dec);
        for (a, b) in vals.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn test_additive_homomorphism() {
        let mut p = CkksParams::toy(2);
        p.n = 1 << 8;
        let ctx = p.build().unwrap();
        let enc = Encoder::new(ctx.n);
        let mut rng = crate::util::Rng::seed_from_u64(13);
        let sk = keygen_secret(&ctx, &mut rng);
        let pk = keygen_public(&ctx, &sk, &mut rng);
        let half = ctx.slots();
        let a: Vec<f64> = (0..half).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..half).map(|i| (i as f64).cos()).collect();
        let cta = encrypt(&ctx, &pk, &enc.encode(&ctx, &a, ctx.scale, 3), &mut rng);
        let ctb = encrypt(&ctx, &pk, &enc.encode(&ctx, &b, ctx.scale, 3), &mut rng);
        let mut sum = cta.clone();
        sum.c0.add_assign(&ctx, &ctb.c0);
        sum.c1.add_assign(&ctx, &ctb.c1);
        let back = enc.decode(&ctx, &decrypt(&ctx, &sk, &sum));
        for i in 0..half {
            assert!((back[i] - (a[i] + b[i])).abs() < 1e-4);
        }
    }
}
