//! A from-scratch RNS-CKKS leveled homomorphic encryption scheme — the
//! substrate the paper evaluates on (Microsoft SEAL in the original; see
//! DESIGN.md substitution #1, and S3–S7 for the per-module design).
//!
//! Provides the full operation algebra of Section 2 of the paper:
//! `Add`, `CMult` (+relinearization), `PMult`, `Rot`, `Rescale`, with
//! leveled modulus chains, hybrid key switching, the canonical-embedding
//! encoder, and the HE-standard security table.
//!
//! ```no_run
//! use lingcn::ckks::{CkksEngine, CkksParams};
//! let engine = CkksEngine::new(CkksParams::toy(3), &[1, 2], 42).unwrap();
//! let ct = engine.encrypt(&[1.0, 2.0, 3.0]);
//! let ct2 = engine.eval.rescale(&engine.eval.square(&ct));
//! let out = engine.decrypt(&ct2);
//! assert!((out[1] - 4.0).abs() < 1e-2);
//! ```

pub mod encoding;
pub mod encrypt;
pub mod eval;
pub mod keys;
pub mod ntt;
pub mod params;
pub mod poly;
pub mod security;
pub mod zq;

pub use encoding::{Encoder, Plaintext, C64};
pub use encrypt::Ciphertext;
pub use eval::{build_eval_keys, Evaluator, OpCounters, OpCounts};
pub use keys::{EvalKeys, PublicKey, SecretKey};
pub use params::{CkksContext, CkksParams};
pub use poly::{limb_parallelism, par_limbs, set_limb_parallelism};

use std::sync::Arc;
use std::sync::Mutex;

/// Convenience bundle: context + encoder + keys + evaluator + RNG.
/// This is what the HE inference engine and the examples hold.
pub struct CkksEngine {
    pub ctx: Arc<CkksContext>,
    pub encoder: Encoder,
    pub sk: SecretKey,
    pub pk: PublicKey,
    pub eval: Evaluator,
    rng: Mutex<crate::util::Rng>,
    /// Content-addressed plaintext cache shared across requests
    /// (DESIGN.md §Perf-2: mask re-encoding dominates serving-path PMult
    /// otherwise).
    pub plaintext_cache: Mutex<std::collections::HashMap<(u64, usize, u64), Plaintext>>,
}

impl CkksEngine {
    /// Build a full engine with Galois keys for `rotation_steps`.
    pub fn new(params: CkksParams, rotation_steps: &[usize], seed: u64) -> anyhow::Result<Self> {
        let ctx = params.build()?;
        let encoder = Encoder::new(ctx.n);
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let sk = keys::keygen_secret(&ctx, &mut rng);
        let pk = keys::keygen_public(&ctx, &sk, &mut rng);
        let ek = Arc::new(build_eval_keys(
            &ctx,
            &encoder,
            &sk,
            rotation_steps,
            false,
            &mut rng,
        ));
        let eval = Evaluator::new(ctx.clone(), ek);
        Ok(CkksEngine {
            ctx,
            encoder,
            sk,
            pk,
            eval,
            rng: Mutex::new(rng),
            plaintext_cache: Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Encode + encrypt a real vector at top level, default scale.
    pub fn encrypt(&self, values: &[f64]) -> Ciphertext {
        let pt = self
            .encoder
            .encode(&self.ctx, values, self.ctx.scale, self.ctx.max_level() + 1);
        let mut rng = self.rng.lock().unwrap();
        encrypt::encrypt(&self.ctx, &self.pk, &pt, &mut *rng)
    }

    /// Encrypt at a given level/limb count (for pre-leveled inputs).
    pub fn encrypt_at(&self, values: &[f64], nq: usize) -> Ciphertext {
        let pt = self.encoder.encode(&self.ctx, values, self.ctx.scale, nq);
        let mut rng = self.rng.lock().unwrap();
        encrypt::encrypt(&self.ctx, &self.pk, &pt, &mut *rng)
    }

    /// Decrypt + decode to a real vector.
    pub fn decrypt(&self, ct: &Ciphertext) -> Vec<f64> {
        let pt = encrypt::decrypt(&self.ctx, &self.sk, ct);
        self.encoder.decode(&self.ctx, &pt)
    }

    /// Encode a plaintext at a ciphertext's level and scale (for PMult).
    pub fn encode_for(&self, values: &[f64], ct: &Ciphertext) -> Plaintext {
        self.encoder.encode(&self.ctx, values, self.ctx.scale, ct.nq())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_engine_doc_example() {
        let engine = CkksEngine::new(CkksParams::toy(3), &[1, 2], 42).unwrap();
        let ct = engine.encrypt(&[1.0, 2.0, 3.0]);
        let ct2 = engine.eval.rescale(&engine.eval.square(&ct));
        let out = engine.decrypt(&ct2);
        assert!((out[0] - 1.0).abs() < 1e-2);
        assert!((out[1] - 4.0).abs() < 1e-2);
        assert!((out[2] - 9.0).abs() < 1e-2);
    }
}
