//! A from-scratch RNS-CKKS leveled homomorphic encryption scheme — the
//! substrate the paper evaluates on (Microsoft SEAL in the original; see
//! DESIGN.md substitution #1, and S3–S7 for the per-module design).
//!
//! Provides the full operation algebra of Section 2 of the paper:
//! `Add`, `CMult` (+relinearization), `PMult`, `Rot`, `Rescale`, with
//! leveled modulus chains, hybrid key switching, the canonical-embedding
//! encoder, and the HE-standard security table.
//!
//! The engine is split along the privacy boundary (DESIGN.md S15):
//! [`EvalEngine`] is the **server half** — context, encoder and evaluation
//! keys only, with no way to decrypt — while [`CkksEngine`] bundles the
//! client key material (secret + public key) *on top of* an `EvalEngine`
//! for trusted single-process use (tests, demos, the `serve --tier he`
//! tier). `CkksEngine` derefs to its `EvalEngine`, so anything written
//! against the server half accepts either.
//!
//! ```no_run
//! use lingcn::ckks::{CkksEngine, CkksParams};
//! let engine = CkksEngine::new(CkksParams::toy(3), &[1, 2], 42).unwrap();
//! let ct = engine.encrypt(&[1.0, 2.0, 3.0]);
//! let ct2 = engine.eval.rescale(&engine.eval.square(&ct));
//! let out = engine.decrypt(&ct2);
//! assert!((out[1] - 4.0).abs() < 1e-2);
//! ```

pub mod arena;
pub mod encoding;
pub mod encrypt;
pub mod eval;
pub mod keys;
pub mod ntt;
pub mod params;
pub mod poly;
pub mod security;
pub mod zq;

pub use arena::{arena_enabled, set_arena_enabled};
pub use encoding::{Encoder, Plaintext, C64};
pub use encrypt::Ciphertext;
pub use eval::{
    build_eval_keys, fused_keyswitch, set_fused_keyswitch, Evaluator, OpCounters, OpCounts,
};
pub use keys::{EvalKeys, KeySwitchKey, PublicKey, SecretKey};
pub use params::{CkksContext, CkksParams};
pub use poly::{limb_parallelism, par_limbs, set_limb_parallelism, RnsPoly};

use std::sync::Arc;
use std::sync::Mutex;

/// The **server half** of the engine: shared context, encoder, evaluator
/// (relinearization + Galois keys) and the cross-request plaintext cache.
/// Holds no secret key and no encryption key — a process that only ever
/// constructs `EvalEngine`s can evaluate on ciphertexts but can neither
/// decrypt them nor forge fresh encryptions under the client's key. This
/// is the type the encrypted serving path (`he_infer::exec`,
/// `wire::server`) is written against.
pub struct EvalEngine {
    pub ctx: Arc<CkksContext>,
    pub encoder: Encoder,
    pub eval: Evaluator,
    /// Content-addressed plaintext cache shared across requests
    /// (DESIGN.md §Perf-2: mask re-encoding dominates serving-path PMult
    /// otherwise).
    pub plaintext_cache: Mutex<std::collections::HashMap<(u64, usize, u64), Plaintext>>,
}

impl EvalEngine {
    /// Assemble the key-free half from a built context and evaluation keys
    /// (typically deserialized from a client's `wire::EvalKeySet`).
    pub fn new(ctx: Arc<CkksContext>, keys: Arc<EvalKeys>) -> Self {
        let encoder = Encoder::new(ctx.n);
        let eval = Evaluator::new(ctx.clone(), keys);
        EvalEngine {
            ctx,
            encoder,
            eval,
            plaintext_cache: Mutex::new(std::collections::HashMap::new()),
        }
    }

    /// Encode a plaintext at a ciphertext's level and scale (for PMult).
    pub fn encode_for(&self, values: &[f64], ct: &Ciphertext) -> Plaintext {
        self.encoder.encode(&self.ctx, values, self.ctx.scale, ct.nq())
    }
}

/// Convenience bundle: an [`EvalEngine`] plus the **client key half**
/// (secret + public key and the encryption RNG). This is what the
/// trusted-single-process paths hold — examples, tests, and the demo
/// `serve --tier he` tier, where encrypt/execute/decrypt all happen in
/// one process. The wire deployment shape keeps the two halves in
/// different processes (`wire::ClientKeys` vs [`EvalEngine`]).
pub struct CkksEngine {
    pub sk: SecretKey,
    pub pk: PublicKey,
    half: EvalEngine,
    rng: Mutex<crate::util::Rng>,
}

impl std::ops::Deref for CkksEngine {
    type Target = EvalEngine;

    fn deref(&self) -> &EvalEngine {
        &self.half
    }
}

impl CkksEngine {
    /// Build a full engine with Galois keys for `rotation_steps`.
    ///
    /// Key generation draws from a single seeded stream in a fixed order
    /// (secret, public, relin, Galois) — `wire::ClientKeys::generate`
    /// mirrors this exactly so the split-process path is bit-identical.
    pub fn new(params: CkksParams, rotation_steps: &[usize], seed: u64) -> anyhow::Result<Self> {
        let ctx = params.build()?;
        let encoder = Encoder::new(ctx.n);
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let sk = keys::keygen_secret(&ctx, &mut rng);
        let pk = keys::keygen_public(&ctx, &sk, &mut rng);
        let ek = Arc::new(build_eval_keys(
            &ctx,
            &encoder,
            &sk,
            rotation_steps,
            false,
            &mut rng,
        ));
        Ok(CkksEngine {
            sk,
            pk,
            half: EvalEngine::new(ctx, ek),
            rng: Mutex::new(rng),
        })
    }

    /// The key-free server half (also reachable via deref coercion).
    pub fn eval_half(&self) -> &EvalEngine {
        &self.half
    }

    /// Encode + encrypt a real vector at top level, default scale.
    pub fn encrypt(&self, values: &[f64]) -> Ciphertext {
        let pt = self
            .encoder
            .encode(&self.ctx, values, self.ctx.scale, self.ctx.max_level() + 1);
        let mut rng = self.rng.lock().unwrap();
        encrypt::encrypt(&self.ctx, &self.pk, &pt, &mut *rng)
    }

    /// Encrypt at a given level/limb count (for pre-leveled inputs).
    pub fn encrypt_at(&self, values: &[f64], nq: usize) -> Ciphertext {
        let pt = self.encoder.encode(&self.ctx, values, self.ctx.scale, nq);
        let mut rng = self.rng.lock().unwrap();
        encrypt::encrypt(&self.ctx, &self.pk, &pt, &mut *rng)
    }

    /// Decrypt + decode to a real vector.
    pub fn decrypt(&self, ct: &Ciphertext) -> Vec<f64> {
        let pt = encrypt::decrypt(&self.ctx, &self.sk, ct);
        self.encoder.decode(&self.ctx, &pt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_engine_doc_example() {
        let engine = CkksEngine::new(CkksParams::toy(3), &[1, 2], 42).unwrap();
        let ct = engine.encrypt(&[1.0, 2.0, 3.0]);
        let ct2 = engine.eval.rescale(&engine.eval.square(&ct));
        let out = engine.decrypt(&ct2);
        assert!((out[0] - 1.0).abs() < 1e-2);
        assert!((out[1] - 4.0).abs() < 1e-2);
        assert!((out[2] - 9.0).abs() < 1e-2);
    }

    #[test]
    fn test_eval_half_shares_keys_and_evaluates() {
        // an EvalEngine assembled from the engine's own eval keys computes
        // the same ciphertexts the bundled engine does
        let engine = CkksEngine::new(CkksParams::toy(2), &[1], 9).unwrap();
        let server = EvalEngine::new(engine.ctx.clone(), engine.eval.keys.clone());
        let ct = engine.encrypt(&[0.5, -0.25, 0.125]);
        let a = engine.eval.rotate(&engine.encoder, &ct, 1);
        let b = server.eval.rotate(&server.encoder, &ct, 1);
        assert_eq!(a.c0, b.c0);
        assert_eq!(a.c1, b.c1);
        let out = engine.decrypt(&b);
        assert!((out[0] + 0.25).abs() < 1e-2);
    }
}
