//! RNS polynomials in `Z_Q[x]/(x^N + 1)`.
//!
//! A polynomial is stored as one residue vector ("limb") per RNS prime.
//! Limb `j` (for `j < nq`) corresponds to context modulus `q_j`; an optional
//! trailing limb over the special prime `P` exists only transiently inside
//! hybrid key switching. Polynomials carry an `is_ntt` flag; all products
//! happen in NTT (evaluation) form, all digit decompositions in coefficient
//! form.

use super::params::CkksContext;
use super::zq;
use crate::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide limb-parallelism degree for the hot per-limb loops
/// (NTT round trips, rescale, key-switch digit spreading). `1` (the
/// default) keeps every loop serial — results are bit-identical either
/// way because all limb work is exact modular arithmetic on disjoint
/// residue vectors, so this is purely a throughput knob (DESIGN.md S14).
static LIMB_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the number of threads `par_limbs` fans out to (clamped to ≥ 1).
pub fn set_limb_parallelism(threads: usize) {
    LIMB_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// Current limb-parallelism degree.
pub fn limb_parallelism() -> usize {
    LIMB_THREADS.load(Ordering::Relaxed)
}

/// Run `f(limb_index, &mut limb)` over every limb, fanning out across the
/// persistent worker pool (`util::pool`; DESIGN.md §Perf-4) when
/// [`set_limb_parallelism`] asked for more than one thread. Limbs are
/// disjoint `&mut` elements, so this is safe and deterministic: each
/// limb's computation is independent of scheduling.
///
/// With `util::pool::set_pooled_spawn(false)` (the ablation baseline)
/// this falls back to the pre-campaign scoped `std::thread` fan-out,
/// which pays a thread-spawn per chunk (~tens of µs) on every call.
/// Late-chain ops with very few limbs stay serial regardless.
pub fn par_limbs<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    // below 3 limbs the fan-out overhead can't amortize — stay serial
    let threads = if items.len() < 3 {
        1
    } else {
        limb_parallelism().min(items.len())
    };
    if threads <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    if crate::util::pool::pooled_spawn() {
        let base = items.as_mut_ptr() as usize;
        let task = |i: usize| {
            // SAFETY: the pool claims each index in 0..len exactly once,
            // so every task holds the only &mut to its element; T: Send
            // lets elements be touched from pool workers; pool::run does
            // not return until all tasks finished.
            let item = unsafe { &mut *(base as *mut T).add(i) };
            f(i, item);
        };
        crate::util::pool::run(threads - 1, items.len(), &task);
        return;
    }
    let per = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (chunk_idx, chunk) in items.chunks_mut(per).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, item) in chunk.iter_mut().enumerate() {
                    f(chunk_idx * per + j, item);
                }
            });
        }
    });
}

#[derive(Clone, Debug, PartialEq)]
pub struct RnsPoly {
    /// Residue vectors, length `nq` (+1 if `has_special`), each of length N.
    pub limbs: Vec<Vec<u64>>,
    /// Number of Q-chain limbs (level + 1).
    pub nq: usize,
    /// Whether a special-prime limb is appended after the Q limbs.
    pub has_special: bool,
    /// Evaluation (NTT) form vs coefficient form.
    pub is_ntt: bool,
}

impl RnsPoly {
    pub fn zero(ctx: &CkksContext, nq: usize, has_special: bool, is_ntt: bool) -> Self {
        let count = nq + has_special as usize;
        RnsPoly {
            limbs: vec![vec![0u64; ctx.n]; count],
            nq,
            has_special,
            is_ntt,
        }
    }

    /// A polynomial backed by possibly-dirty arena buffers (`ckks::arena`;
    /// DESIGN.md §Perf-6). The caller must overwrite **every word of every
    /// limb** before reading any — use [`RnsPoly::zero`] when that is not
    /// guaranteed.
    pub fn scratch(ctx: &CkksContext, nq: usize, has_special: bool, is_ntt: bool) -> Self {
        let count = nq + has_special as usize;
        RnsPoly {
            limbs: super::arena::take_limbs(ctx.n, count),
            nq,
            has_special,
            is_ntt,
        }
    }

    /// Arena-backed scratch shaped like `self` (same dirty-buffer contract
    /// as [`RnsPoly::scratch`]).
    pub fn scratch_like(&self) -> Self {
        RnsPoly {
            limbs: super::arena::take_limbs(self.limbs[0].len(), self.limb_count()),
            nq: self.nq,
            has_special: self.has_special,
            is_ntt: self.is_ntt,
        }
    }

    /// Return this polynomial's limb buffers to the thread-local arena.
    pub fn recycle(self) {
        super::arena::recycle_limbs(self.limbs);
    }

    /// Modulus index in the context for limb slot `idx`.
    fn mod_index(&self, ctx: &CkksContext, idx: usize) -> usize {
        if idx < self.nq {
            idx
        } else {
            debug_assert!(self.has_special);
            ctx.moduli.len() // virtual index of the special prime
        }
    }

    pub fn limb_count(&self) -> usize {
        self.nq + self.has_special as usize
    }

    /// Whether every residue word lies in `[0, q)` for its limb's
    /// modulus — the representation invariant all modular kernels assume
    /// (checked at the wire boundary: a forged-but-checksummed frame must
    /// be rejected before it reaches unchecked modular arithmetic).
    pub fn is_reduced(&self, ctx: &CkksContext) -> bool {
        (0..self.limb_count()).all(|idx| {
            let q = ctx.modulus(self.mod_index(ctx, idx));
            self.limbs[idx].iter().all(|&w| w < q)
        })
    }

    /// Build from signed i64 coefficients (centered representation), reduced
    /// into every limb. Coefficient form.
    pub fn from_signed_coeffs(ctx: &CkksContext, coeffs: &[i64], nq: usize) -> Self {
        assert_eq!(coeffs.len(), ctx.n);
        let mut p = RnsPoly::zero(ctx, nq, false, false);
        for (idx, limb) in p.limbs.iter_mut().enumerate() {
            let q = ctx.modulus(idx);
            for (c, out) in coeffs.iter().zip(limb.iter_mut()) {
                *out = (*c).rem_euclid(q as i64) as u64;
            }
        }
        p
    }

    /// Build from large signed coefficients given as i128 (used by the
    /// encoder, whose values can exceed 63 bits at scale Δ²).
    pub fn from_signed_coeffs_i128(ctx: &CkksContext, coeffs: &[i128], nq: usize) -> Self {
        assert_eq!(coeffs.len(), ctx.n);
        let mut p = RnsPoly::zero(ctx, nq, false, false);
        for (idx, limb) in p.limbs.iter_mut().enumerate() {
            let q = ctx.modulus(idx) as i128;
            for (c, out) in coeffs.iter().zip(limb.iter_mut()) {
                *out = (*c).rem_euclid(q) as u64;
            }
        }
        p
    }

    /// In-place forward NTT on every limb (limb-parallel via [`par_limbs`]).
    pub fn ntt_forward(&mut self, ctx: &CkksContext) {
        assert!(!self.is_ntt, "already in NTT form");
        let nq = self.nq;
        let special = ctx.moduli.len();
        par_limbs(&mut self.limbs, |idx, limb| {
            let m = if idx < nq { idx } else { special };
            ctx.ntt_for(m).forward(limb);
        });
        self.is_ntt = true;
    }

    /// In-place inverse NTT on every limb (limb-parallel via [`par_limbs`]).
    pub fn ntt_inverse(&mut self, ctx: &CkksContext) {
        assert!(self.is_ntt, "already in coefficient form");
        let nq = self.nq;
        let special = ctx.moduli.len();
        par_limbs(&mut self.limbs, |idx, limb| {
            let m = if idx < nq { idx } else { special };
            ctx.ntt_for(m).inverse(limb);
        });
        self.is_ntt = false;
    }

    fn check_compat(&self, other: &RnsPoly) {
        assert_eq!(self.nq, other.nq, "limb count mismatch");
        assert_eq!(self.has_special, other.has_special, "special limb mismatch");
        assert_eq!(self.is_ntt, other.is_ntt, "domain mismatch");
    }

    pub fn add_assign(&mut self, ctx: &CkksContext, other: &RnsPoly) {
        self.check_compat(other);
        let (nq, special) = (self.nq, ctx.moduli.len());
        par_limbs(&mut self.limbs, |idx, limb| {
            let q = ctx.modulus(if idx < nq { idx } else { special });
            for (a, &b) in limb.iter_mut().zip(&other.limbs[idx]) {
                *a = zq::add_mod(*a, b, q);
            }
        });
    }

    pub fn sub_assign(&mut self, ctx: &CkksContext, other: &RnsPoly) {
        self.check_compat(other);
        let (nq, special) = (self.nq, ctx.moduli.len());
        par_limbs(&mut self.limbs, |idx, limb| {
            let q = ctx.modulus(if idx < nq { idx } else { special });
            for (a, &b) in limb.iter_mut().zip(&other.limbs[idx]) {
                *a = zq::sub_mod(*a, b, q);
            }
        });
    }

    pub fn neg_assign(&mut self, ctx: &CkksContext) {
        let (nq, special) = (self.nq, ctx.moduli.len());
        par_limbs(&mut self.limbs, |idx, limb| {
            let q = ctx.modulus(if idx < nq { idx } else { special });
            for a in limb.iter_mut() {
                *a = zq::neg_mod(*a, q);
            }
        });
    }

    /// Pointwise product (both operands must be in NTT form). The output
    /// comes from the scratch arena — every word is written below, so the
    /// pre-campaign clone-then-overwrite memcpy is dead weight (§Perf-6).
    pub fn mul(&self, ctx: &CkksContext, other: &RnsPoly) -> RnsPoly {
        self.check_compat(other);
        assert!(self.is_ntt, "mul requires NTT form");
        let mut out = self.scratch_like();
        let (nq, special) = (self.nq, ctx.moduli.len());
        par_limbs(&mut out.limbs, |idx, dst| {
            let br = ctx.barrett_for(if idx < nq { idx } else { special });
            for ((d, &a), &b) in dst.iter_mut().zip(&self.limbs[idx]).zip(&other.limbs[idx]) {
                *d = br.mul(a, b);
            }
        });
        out
    }

    pub fn mul_assign(&mut self, ctx: &CkksContext, other: &RnsPoly) {
        self.check_compat(other);
        assert!(self.is_ntt, "mul requires NTT form");
        let (nq, special) = (self.nq, ctx.moduli.len());
        par_limbs(&mut self.limbs, |idx, limb| {
            let br = ctx.barrett_for(if idx < nq { idx } else { special });
            for (a, &b) in limb.iter_mut().zip(&other.limbs[idx]) {
                *a = br.mul(*a, b);
            }
        });
    }

    /// Multiply-accumulate: `self += a * b` (all NTT form).
    pub fn mul_acc(&mut self, ctx: &CkksContext, a: &RnsPoly, b: &RnsPoly) {
        a.check_compat(b);
        self.check_compat(a);
        assert!(self.is_ntt);
        let (nq, special) = (self.nq, ctx.moduli.len());
        par_limbs(&mut self.limbs, |idx, dst| {
            let m = if idx < nq { idx } else { special };
            let q = ctx.modulus(m);
            let br = ctx.barrett_for(m);
            let (av, bv) = (&a.limbs[idx], &b.limbs[idx]);
            for i in 0..dst.len() {
                let p = br.mul(av[i], bv[i]);
                dst[i] = zq::add_mod(dst[i], p, q);
            }
        });
    }

    /// Multiply every limb by a scalar (given per-limb, already reduced)
    /// via a Shoup-precomputed constant per limb — same trick
    /// `rescale_last` uses, replacing an eager 128-bit `mul_mod` per
    /// coefficient with one widening multiply and a subtraction.
    pub fn mul_scalar_per_limb(&mut self, ctx: &CkksContext, scalars: &[u64]) {
        assert_eq!(scalars.len(), self.limb_count());
        let (nq, special) = (self.nq, ctx.moduli.len());
        par_limbs(&mut self.limbs, |idx, limb| {
            let q = ctx.modulus(if idx < nq { idx } else { special });
            let sm = zq::ShoupMul::new(scalars[idx] % q, q);
            for a in limb.iter_mut() {
                *a = sm.mul(*a, q);
            }
        });
    }

    /// Drop the last Q limb (RNS modulus reduction without scaling). The
    /// decrypted value is unchanged as long as it fits the smaller modulus.
    pub fn drop_last_limb(&mut self) {
        assert!(!self.has_special);
        assert!(self.nq > 1, "cannot drop below one limb");
        self.limbs.truncate(self.nq - 1);
        self.nq -= 1;
    }

    /// Truncate to `nq` limbs (modulus switch by dropping residues).
    pub fn truncate_to(&mut self, nq: usize) {
        assert!(!self.has_special);
        assert!(nq >= 1 && nq <= self.nq);
        self.limbs.truncate(nq);
        self.nq = nq;
    }

    /// Exact rescale: divide by the last prime q_m and round, dropping the
    /// limb. Must be in coefficient form. This is the CKKS `Rescale` core.
    pub fn rescale_last(&mut self, ctx: &CkksContext) {
        assert!(!self.is_ntt, "rescale requires coefficient form");
        assert!(!self.has_special);
        let m = self.nq - 1;
        assert!(m >= 1, "cannot rescale at level 0");
        let q_m = ctx.moduli[m];
        let half = q_m / 2;
        let last = self.limbs.pop().unwrap();
        self.nq -= 1;
        par_limbs(&mut self.limbs, |j, limb| {
            let q_j = ctx.moduli[j];
            let q_m_mod_j = ctx.mod_last[m][j];
            let br = ctx.barrett_for(j);
            let inv_shoup = &ctx.inv_last_shoup[m][j];
            for i in 0..limb.len() {
                // centered lift of the dropped residue for round-to-nearest
                let r = last[i];
                let mut t = zq::sub_mod(limb[i], br.reduce_u64(r), q_j);
                if r > half {
                    t = zq::add_mod(t, q_m_mod_j, q_j);
                }
                limb[i] = inv_shoup.mul(t, q_j);
            }
        });
    }

    /// Galois automorphism applied in NTT (evaluation) form: with the
    /// CT/bit-reversed layout, NTT index j holds a(ψ^{2·brv(j)+1}), so
    /// τ_g is a pure slot permutation — no NTT round-trip (DESIGN.md
    /// §Perf-3). `perm` comes from [`ntt_automorphism_permutation`].
    pub fn automorphism_ntt(&self, perm: &[usize]) -> RnsPoly {
        assert!(self.is_ntt, "NTT-domain automorphism needs NTT form");
        assert_eq!(perm.len(), self.limbs[0].len(), "permutation/ring mismatch");
        // scratch, not clone: `perm` is a permutation of 0..N, so the loop
        // writes every word — the pre-campaign clone paid a full memcpy
        // only to overwrite it (§Perf-6)
        let mut out = self.scratch_like();
        par_limbs(&mut out.limbs, |idx, dst| {
            let src = &self.limbs[idx];
            for (j, &k) in perm.iter().enumerate() {
                dst[j] = src[k];
            }
        });
        out
    }

    /// Galois automorphism x -> x^g (coefficient form), g odd mod 2N.
    pub fn automorphism(&self, ctx: &CkksContext, g: usize) -> RnsPoly {
        assert!(!self.is_ntt, "automorphism implemented in coefficient form");
        let n = ctx.n;
        assert!(g % 2 == 1 && g < 2 * n);
        let mut out = RnsPoly::zero(ctx, self.nq, self.has_special, false);
        for idx in 0..self.limb_count() {
            let q = ctx.modulus(self.mod_index(ctx, idx));
            let src = &self.limbs[idx];
            let dst = &mut out.limbs[idx];
            for j in 0..n {
                let k = (j * g) % (2 * n);
                if k < n {
                    dst[k] = src[j];
                } else {
                    dst[k - n] = zq::neg_mod(src[j], q);
                }
            }
        }
        out
    }

    /// Sample uniform in R_Q (NTT form is fine since uniform is
    /// NTT-invariant; we mark it coefficient form for generality).
    pub fn sample_uniform(ctx: &CkksContext, nq: usize, has_special: bool, rng: &mut Rng) -> Self {
        let mut p = RnsPoly::zero(ctx, nq, has_special, false);
        for idx in 0..p.limb_count() {
            let q = ctx.modulus(p.mod_index(ctx, idx));
            for a in p.limbs[idx].iter_mut() {
                *a = rng.gen_below(q);
            }
        }
        p
    }

    /// Sample ternary {-1, 0, 1} (the secret-key distribution).
    pub fn sample_ternary(ctx: &CkksContext, nq: usize, has_special: bool, rng: &mut Rng) -> Self {
        let n = ctx.n;
        let signs: Vec<i64> = (0..n).map(|_| rng.gen_range_i64(-1, 1)).collect();
        let mut p = RnsPoly::zero(ctx, nq, has_special, false);
        for idx in 0..p.limb_count() {
            let q = ctx.modulus(p.mod_index(ctx, idx)) as i64;
            for (a, &s) in p.limbs[idx].iter_mut().zip(&signs) {
                *a = s.rem_euclid(q) as u64;
            }
        }
        p
    }

    /// Sample a discrete Gaussian error (sigma ≈ 3.2, rounded Box-Muller).
    pub fn sample_gaussian(ctx: &CkksContext, nq: usize, has_special: bool, rng: &mut Rng) -> Self {
        let n = ctx.n;
        const SIGMA: f64 = 3.2;
        let mut vals = Vec::with_capacity(n);
        while vals.len() < n {
            let u1: f64 = rng.gen_f64().max(f64::MIN_POSITIVE);
            let u2: f64 = rng.gen_f64();
            let r = (-2.0 * u1.ln()).sqrt() * SIGMA;
            let theta = 2.0 * std::f64::consts::PI * u2;
            vals.push((r * theta.cos()).round() as i64);
            if vals.len() < n {
                vals.push((r * theta.sin()).round() as i64);
            }
        }
        let mut p = RnsPoly::zero(ctx, nq, has_special, false);
        for idx in 0..p.limb_count() {
            let q = ctx.modulus(p.mod_index(ctx, idx)) as i64;
            for (a, &v) in p.limbs[idx].iter_mut().zip(&vals) {
                *a = v.rem_euclid(q) as u64;
            }
        }
        p
    }

    /// Reconstruct centered signed coefficients as i128 via CRT over the
    /// first `min(3, nq)` limbs. Valid while |value| < product(those primes)/2;
    /// used by decryption (messages + noise are far below Q).
    pub fn to_signed_coeffs_i128(&self, ctx: &CkksContext) -> Vec<i128> {
        assert!(!self.is_ntt, "need coefficient form");
        assert!(!self.has_special);
        let use_limbs = self.nq.min(3);
        let primes: Vec<u128> = (0..use_limbs).map(|j| ctx.moduli[j] as u128).collect();
        let prod: u128 = primes.iter().product();
        // CRT basis: e_j = (prod/p_j) * inv(prod/p_j mod p_j)
        let basis: Vec<u128> = (0..use_limbs)
            .map(|j| {
                let pj = primes[j];
                let rest = prod / pj;
                let inv = zq::inv_mod((rest % pj) as u64, pj as u64) as u128;
                // rest * inv mod prod — rest < 2^120, inv < 2^60: careful mulmod
                mulmod_u128(rest, inv, prod)
            })
            .collect();
        let half = prod / 2;
        (0..ctx.n)
            .map(|i| {
                let mut acc: u128 = 0;
                for j in 0..use_limbs {
                    let term = mulmod_u128(self.limbs[j][i] as u128, basis[j], prod);
                    acc = (acc + term) % prod;
                }
                if acc > half {
                    (acc as i128).wrapping_sub(prod as i128)
                } else {
                    acc as i128
                }
            })
            .collect()
    }
}

/// Permutation implementing the Galois automorphism τ_g in NTT domain:
/// `out[j] = in[perm[j]]` where NTT index j evaluates at ψ^{2·brv(j)+1}.
pub fn ntt_automorphism_permutation(n: usize, g: usize) -> Vec<usize> {
    let bits = n.trailing_zeros();
    let brv = |x: usize| x.reverse_bits() >> (usize::BITS - bits);
    let two_n = 2 * n;
    (0..n)
        .map(|j| {
            let e = (2 * brv(j) + 1) * g % two_n;
            brv((e - 1) / 2)
        })
        .collect()
}

/// `(a*b) mod m` for u128 operands without overflow (binary long mult).
fn mulmod_u128(mut a: u128, mut b: u128, m: u128) -> u128 {
    a %= m;
    let mut r: u128 = 0;
    while b > 0 {
        if b & 1 == 1 {
            r = r.checked_add(a).map(|v| v % m).unwrap_or_else(|| {
                // (r + a) mod m without overflow: both < m < 2^127
                let t = m - a;
                if r >= t {
                    r - t
                } else {
                    r + a
                }
            });
        }
        b >>= 1;
        if b > 0 {
            a = a.checked_add(a).map(|v| v % m).unwrap_or_else(|| {
                let t = m - a;
                if a >= t {
                    a - t
                } else {
                    a + a
                }
            });
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::params::CkksParams;

    fn ctx() -> std::sync::Arc<crate::ckks::params::CkksContext> {
        let mut p = CkksParams::toy(3);
        p.n = 1 << 6; // tiny for tests
        p.build().unwrap()
    }

    #[test]
    fn test_signed_roundtrip() {
        let c = ctx();
        let coeffs: Vec<i64> = (0..c.n).map(|i| (i as i64 - 32) * 1000).collect();
        let p = RnsPoly::from_signed_coeffs(&c, &coeffs, 4);
        let back = p.to_signed_coeffs_i128(&c);
        for (a, b) in coeffs.iter().zip(&back) {
            assert_eq!(*a as i128, *b);
        }
    }

    #[test]
    fn test_add_sub_neg_roundtrip() {
        let c = ctx();
        let mut rng = crate::util::Rng::seed_from_u64(1);
        let a = RnsPoly::sample_uniform(&c, 4, false, &mut rng);
        let b = RnsPoly::sample_uniform(&c, 4, false, &mut rng);
        let mut s = a.clone();
        s.add_assign(&c, &b);
        s.sub_assign(&c, &b);
        assert_eq!(s, a);
        let mut n2 = a.clone();
        n2.neg_assign(&c);
        n2.neg_assign(&c);
        assert_eq!(n2, a);
    }

    #[test]
    fn test_ntt_mul_consistency_rns() {
        // (a*b) computed limb-wise in NTT form must equal the integer
        // negacyclic product reduced mod each prime.
        let c = ctx();
        let av: Vec<i64> = (0..c.n).map(|i| (i % 5) as i64 - 2).collect();
        let bv: Vec<i64> = (0..c.n).map(|i| (i % 3) as i64 - 1).collect();
        let mut a = RnsPoly::from_signed_coeffs(&c, &av, 2);
        let mut b = RnsPoly::from_signed_coeffs(&c, &bv, 2);
        a.ntt_forward(&c);
        b.ntt_forward(&c);
        let mut prod = a.mul(&c, &b);
        prod.ntt_inverse(&c);
        let got = prod.to_signed_coeffs_i128(&c);
        // naive signed negacyclic product
        let n = c.n;
        let mut want = vec![0i128; n];
        for i in 0..n {
            for j in 0..n {
                let p = av[i] as i128 * bv[j] as i128;
                if i + j < n {
                    want[i + j] += p;
                } else {
                    want[i + j - n] -= p;
                }
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn test_rescale_divides_by_last_prime() {
        let c = ctx();
        let q_last = c.moduli[3];
        // value divisible by q_last should rescale exactly
        let coeffs: Vec<i64> = (0..c.n).map(|i| (i as i64 - 10) * q_last as i64).collect();
        let mut p = RnsPoly::from_signed_coeffs(&c, &coeffs, 4);
        p.rescale_last(&c);
        assert_eq!(p.nq, 3);
        let back = p.to_signed_coeffs_i128(&c);
        for (i, b) in back.iter().enumerate() {
            assert_eq!(*b, (i as i128 - 10), "coeff {i}");
        }
    }

    #[test]
    fn test_rescale_rounds_to_nearest() {
        let c = ctx();
        let q_last = c.moduli[3] as i64;
        // value = 3*q + r with r near q/2: check rounding
        let r_small = 5i64;
        let r_big = q_last - 5;
        let coeffs: Vec<i64> = (0..c.n)
            .map(|i| if i % 2 == 0 { 3 * q_last + r_small } else { 3 * q_last + r_big })
            .collect();
        let mut p = RnsPoly::from_signed_coeffs(&c, &coeffs, 4);
        p.rescale_last(&c);
        let back = p.to_signed_coeffs_i128(&c);
        for (i, b) in back.iter().enumerate() {
            let want = if i % 2 == 0 { 3 } else { 4 }; // round(3 + ~1) = 4
            assert_eq!(*b, want, "coeff {i}");
        }
    }

    #[test]
    fn test_automorphism_composition() {
        // applying g then g^{-1} mod 2N must be identity
        let c = ctx();
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let a = RnsPoly::sample_uniform(&c, 2, false, &mut rng);
        let two_n = 2 * c.n;
        let g = 5usize;
        // find inverse of 5 mod 2N
        let mut g_inv = 0;
        for cand in (1..two_n).step_by(2) {
            if (cand * g) % two_n == 1 {
                g_inv = cand;
                break;
            }
        }
        let b = a.automorphism(&c, g).automorphism(&c, g_inv);
        assert_eq!(a, b);
    }

    #[test]
    fn test_drop_limb_preserves_small_values() {
        let c = ctx();
        let coeffs: Vec<i64> = (0..c.n).map(|i| i as i64 - 30).collect();
        let mut p = RnsPoly::from_signed_coeffs(&c, &coeffs, 4);
        p.truncate_to(2);
        let back = p.to_signed_coeffs_i128(&c);
        for (a, b) in coeffs.iter().zip(&back) {
            assert_eq!(*a as i128, *b);
        }
    }

    #[test]
    fn test_par_limbs_indices_and_coverage() {
        // every index visited exactly once, with the right element, at any
        // parallelism degree (including degrees above the item count),
        // through both the persistent pool and the scoped-spawn fallback
        for pooled in [true, false] {
            crate::util::pool::set_pooled_spawn(pooled);
            for threads in [1usize, 2, 3, 8, 64] {
                set_limb_parallelism(threads);
                let mut items: Vec<u64> = (0..13).collect();
                par_limbs(&mut items, |i, v| {
                    assert_eq!(*v, i as u64);
                    *v = 1000 + i as u64;
                });
                assert_eq!(items, (1000..1013).collect::<Vec<u64>>(), "pooled={pooled}");
            }
        }
        crate::util::pool::set_pooled_spawn(true);
        set_limb_parallelism(1);
    }

    #[test]
    fn test_limb_parallel_ntt_and_rescale_bit_identical() {
        // the par_limbs path is a pure scheduling change: NTT round trips
        // and rescale must produce bit-identical limbs at any thread count
        // under either spawn mode (pool or scoped threads)
        let c = ctx();
        let mut rng = crate::util::Rng::seed_from_u64(17);
        let base = RnsPoly::sample_uniform(&c, 4, false, &mut rng);

        set_limb_parallelism(1);
        let mut serial = base.clone();
        serial.ntt_forward(&c);
        serial.ntt_inverse(&c);
        serial.rescale_last(&c);

        for pooled in [true, false] {
            crate::util::pool::set_pooled_spawn(pooled);
            for threads in [2usize, 4, 8] {
                set_limb_parallelism(threads);
                let mut parallel = base.clone();
                parallel.ntt_forward(&c);
                parallel.ntt_inverse(&c);
                parallel.rescale_last(&c);
                assert_eq!(serial, parallel, "pooled={pooled} threads={threads}");
            }
        }
        crate::util::pool::set_pooled_spawn(true);
        set_limb_parallelism(1);
    }

    #[test]
    fn test_scratch_ops_bit_identical_and_shoup_scalar() {
        // arena-backed mul/automorphism_ntt and the Shoup scalar path must
        // equal the plain paths bit for bit, including on recycled (dirty)
        // buffers the second time around
        let c = ctx();
        let mut rng = crate::util::Rng::seed_from_u64(23);
        let mut a = RnsPoly::sample_uniform(&c, 3, false, &mut rng);
        let mut b = RnsPoly::sample_uniform(&c, 3, false, &mut rng);
        a.is_ntt = true;
        b.is_ntt = true;
        let perm = ntt_automorphism_permutation(c.n, 5);
        for round in 0..3 {
            // round 0 allocates, later rounds reuse recycled buffers
            let prod = a.mul(&c, &b);
            let mut want = a.clone();
            want.mul_assign(&c, &b);
            assert_eq!(prod, want, "round {round}");
            let rot = a.automorphism_ntt(&perm);
            for idx in 0..a.limb_count() {
                for (j, &k) in perm.iter().enumerate() {
                    assert_eq!(rot.limbs[idx][j], a.limbs[idx][k]);
                }
            }
            prod.recycle();
            rot.recycle();
        }
        // ShoupMul scalar path == eager mul_mod path
        let scalars: Vec<u64> = (0..3).map(|i| 0x1234_5678 + i as u64).collect();
        let mut shoup = a.clone();
        shoup.mul_scalar_per_limb(&c, &scalars);
        for idx in 0..3 {
            let q = c.moduli[idx];
            let s = scalars[idx] % q;
            for (got, &orig) in shoup.limbs[idx].iter().zip(&a.limbs[idx]) {
                assert_eq!(*got, zq::mul_mod(orig, s, q));
            }
        }
    }

    #[test]
    fn test_mulmod_u128() {
        let m = (1u128 << 120) - 159;
        let a = (1u128 << 119) + 12345;
        let b = (1u128 << 118) + 999;
        // compare against naive via modular exponent identity:
        // (a*b) mod m computed with split: a*b = a*(b_hi*2^64 + b_lo)
        let b_hi = b >> 64;
        let b_lo = b & ((1u128 << 64) - 1);
        let t1 = mulmod_u128(a, b_hi, m);
        let t2 = mulmod_u128(t1, 1u128 << 64, m);
        let t3 = mulmod_u128(a, b_lo, m);
        assert_eq!(mulmod_u128(a, b, m), (t2 + t3) % m);
    }
}
