//! Negacyclic number-theoretic transform over `Z_q[x]/(x^n + 1)`.
//!
//! Forward transform is the Cooley-Tukey decimation-in-time variant with the
//! 2n-th root powers stored in bit-reversed order; the inverse is
//! Gentleman-Sande. Multiplying two transformed polynomials pointwise and
//! inverting yields the negacyclic product — the core primitive behind every
//! CKKS ciphertext operation. Butterflies use Shoup multiplication with lazy
//! reduction (values kept in [0, 2q) inside the loop) — see §Perf-1 in
//! DESIGN.md.

use super::zq::{self, ShoupMul};

/// Precomputed NTT tables for one (prime, degree) pair.
pub struct NttTable {
    pub n: usize,
    pub q: u64,
    /// psi^bitrev(i) for CT forward butterflies.
    roots: Vec<ShoupMul>,
    /// psi^{-bitrev(i)} for GS inverse butterflies.
    inv_roots: Vec<ShoupMul>,
    /// n^{-1} mod q for the final inverse scaling.
    n_inv: ShoupMul,
}

fn bit_reverse(x: usize, bits: u32) -> usize {
    x.reverse_bits() >> (usize::BITS - bits)
}

impl NttTable {
    pub fn new(n: usize, q: u64) -> Self {
        assert!(n.is_power_of_two(), "degree must be a power of two");
        let psi = zq::primitive_2nth_root(n, q);
        let psi_inv = zq::inv_mod(psi, q);
        let bits = n.trailing_zeros();
        let mut roots = Vec::with_capacity(n);
        let mut inv_roots = Vec::with_capacity(n);
        // powers in bit-reversed order
        let mut pow_f = vec![0u64; n];
        let mut pow_i = vec![0u64; n];
        pow_f[0] = 1;
        pow_i[0] = 1;
        for i in 1..n {
            pow_f[i] = zq::mul_mod(pow_f[i - 1], psi, q);
            pow_i[i] = zq::mul_mod(pow_i[i - 1], psi_inv, q);
        }
        for i in 0..n {
            let r = bit_reverse(i, bits);
            roots.push(ShoupMul::new(pow_f[r], q));
            inv_roots.push(ShoupMul::new(pow_i[r], q));
        }
        let n_inv = ShoupMul::new(zq::inv_mod(n as u64, q), q);
        NttTable {
            n,
            q,
            roots,
            inv_roots,
            n_inv,
        }
    }

    /// In-place forward negacyclic NTT (coefficient -> evaluation order).
    pub fn forward(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let n = self.n;
        let q = self.q;
        let two_q = 2 * q;
        let mut t = n;
        let mut m = 1;
        while m < n {
            t >>= 1;
            for i in 0..m {
                let j1 = 2 * i * t;
                let s = self.roots[m + i];
                for j in j1..j1 + t {
                    // lazy CT butterfly: inputs < 2q, outputs < 2q
                    let mut u = a[j];
                    if u >= two_q {
                        u -= two_q;
                    }
                    let v = s.mul_lazy(a[j + t], q);
                    a[j] = u + v;
                    a[j + t] = u + two_q - v;
                }
            }
            m <<= 1;
        }
        // final full reduction to [0, q)
        for x in a.iter_mut() {
            let mut v = *x;
            if v >= two_q {
                v -= two_q;
            }
            if v >= q {
                v -= q;
            }
            *x = v;
        }
    }

    /// In-place inverse negacyclic NTT (evaluation -> coefficient order).
    pub fn inverse(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let n = self.n;
        let q = self.q;
        let two_q = 2 * q;
        let mut t = 1;
        let mut m = n;
        while m > 1 {
            let h = m >> 1;
            let mut j1 = 0;
            for i in 0..h {
                let s = self.inv_roots[h + i];
                for j in j1..j1 + t {
                    // lazy GS butterfly
                    let u = a[j];
                    let v = a[j + t];
                    let mut s_uv = u + v;
                    if s_uv >= two_q {
                        s_uv -= two_q;
                    }
                    a[j] = s_uv;
                    a[j + t] = s.mul_lazy(u + two_q - v, q);
                }
                j1 += 2 * t;
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            let mut v = *x;
            if v >= two_q {
                v -= two_q;
            }
            if v >= q {
                v -= q;
            }
            *x = self.n_inv.mul(v, q);
        }
    }
}

/// Schoolbook negacyclic product, used only as a test oracle.
#[cfg(test)]
pub fn negacyclic_mul_naive(a: &[u64], b: &[u64], q: u64) -> Vec<u64> {
    let n = a.len();
    let mut out = vec![0u64; n];
    for i in 0..n {
        for j in 0..n {
            let p = zq::mul_mod(a[i], b[j], q);
            let k = i + j;
            if k < n {
                out[k] = zq::add_mod(out[k], p, q);
            } else {
                out[k - n] = zq::sub_mod(out[k - n], p, q);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_poly(n: usize, q: u64, seed: u64) -> Vec<u64> {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        (0..n).map(|_| rng.gen_below(q)).collect()
    }

    #[test]
    fn test_forward_inverse_roundtrip() {
        for n in [8usize, 64, 1024] {
            let q = zq::gen_ntt_primes(45, n, 1, &[])[0];
            let tbl = NttTable::new(n, q);
            let a = rand_poly(n, q, 7);
            let mut b = a.clone();
            tbl.forward(&mut b);
            tbl.inverse(&mut b);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn test_ntt_mul_matches_naive() {
        for n in [8usize, 32, 128] {
            let q = zq::gen_ntt_primes(40, n, 1, &[])[0];
            let tbl = NttTable::new(n, q);
            let a = rand_poly(n, q, 1);
            let b = rand_poly(n, q, 2);
            let want = negacyclic_mul_naive(&a, &b, q);
            let mut fa = a.clone();
            let mut fb = b.clone();
            tbl.forward(&mut fa);
            tbl.forward(&mut fb);
            let mut fc: Vec<u64> = fa
                .iter()
                .zip(&fb)
                .map(|(&x, &y)| zq::mul_mod(x, y, q))
                .collect();
            tbl.inverse(&mut fc);
            assert_eq!(fc, want, "n={n}");
        }
    }

    #[test]
    fn test_property_roundtrip_randomized() {
        // property: inverse(forward(a)) == a for random polynomials across
        // many seeds, sizes, and prime widths
        for n in [8usize, 16, 32, 64, 128] {
            for (pi, bits) in [30u32, 40, 50].iter().enumerate() {
                let q = zq::gen_ntt_primes(*bits, n, 1, &[])[0];
                let tbl = NttTable::new(n, q);
                for seed in 0..6u64 {
                    let a = rand_poly(n, q, 1000 + seed * 31 + pi as u64);
                    let mut b = a.clone();
                    tbl.forward(&mut b);
                    tbl.inverse(&mut b);
                    assert_eq!(a, b, "n={n} bits={bits} seed={seed}");
                }
            }
        }
    }

    #[test]
    fn test_property_negacyclic_product_matches_schoolbook() {
        // property: pointwise NTT product == schoolbook negacyclic product
        // for random polynomial pairs across seeds and small sizes
        for n in [8usize, 16, 32] {
            let q = zq::gen_ntt_primes(40, n, 1, &[])[0];
            let tbl = NttTable::new(n, q);
            for seed in 0..8u64 {
                let a = rand_poly(n, q, 2000 + seed);
                let b = rand_poly(n, q, 3000 + seed);
                let want = negacyclic_mul_naive(&a, &b, q);
                let (mut fa, mut fb) = (a.clone(), b.clone());
                tbl.forward(&mut fa);
                tbl.forward(&mut fb);
                let mut fc: Vec<u64> = fa
                    .iter()
                    .zip(&fb)
                    .map(|(&x, &y)| zq::mul_mod(x, y, q))
                    .collect();
                tbl.inverse(&mut fc);
                assert_eq!(fc, want, "n={n} seed={seed}");
            }
        }
    }

    #[test]
    fn test_property_forward_outputs_fully_reduced() {
        // the lazy butterflies must still hand back values in [0, q)
        for n in [16usize, 64] {
            let q = zq::gen_ntt_primes(45, n, 1, &[])[0];
            let tbl = NttTable::new(n, q);
            for seed in 0..4u64 {
                let mut a = rand_poly(n, q, 4000 + seed);
                tbl.forward(&mut a);
                assert!(a.iter().all(|&x| x < q));
                tbl.inverse(&mut a);
                assert!(a.iter().all(|&x| x < q));
            }
        }
    }

    #[test]
    fn test_negacyclic_wraparound_sign() {
        // x^{n-1} * x = x^n = -1 mod (x^n+1)
        let n = 16;
        let q = zq::gen_ntt_primes(40, n, 1, &[])[0];
        let tbl = NttTable::new(n, q);
        let mut a = vec![0u64; n];
        a[n - 1] = 1;
        let mut b = vec![0u64; n];
        b[1] = 1;
        tbl.forward(&mut a);
        tbl.forward(&mut b);
        let mut c: Vec<u64> = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| zq::mul_mod(x, y, q))
            .collect();
        tbl.inverse(&mut c);
        assert_eq!(c[0], q - 1); // -1
        assert!(c[1..].iter().all(|&x| x == 0));
    }

    #[test]
    fn test_linearity() {
        let n = 64;
        let q = zq::gen_ntt_primes(40, n, 1, &[])[0];
        let tbl = NttTable::new(n, q);
        let a = rand_poly(n, q, 3);
        let b = rand_poly(n, q, 4);
        let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| zq::add_mod(x, y, q)).collect();
        let (mut fa, mut fb, mut fs) = (a, b, sum);
        tbl.forward(&mut fa);
        tbl.forward(&mut fb);
        tbl.forward(&mut fs);
        for i in 0..n {
            assert_eq!(fs[i], zq::add_mod(fa[i], fb[i], q));
        }
    }
}
