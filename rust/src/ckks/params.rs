//! CKKS parameter sets and the shared precomputation context.
//!
//! A parameter set fixes the ring degree `N`, the RNS modulus chain
//! `q_0, q_1, ..., q_L` (one ~`scale_bits`-bit prime per multiplicative
//! level plus a larger base prime `q_0`), and one special prime `P` used
//! exclusively for hybrid key switching. The paper's Table 6 settings map
//! onto this directly: `L` = mult level, `p` = scale_bits, `q0` = q0_bits.

use super::ntt::NttTable;
use super::zq;
use std::sync::Arc;

/// Builder-style description of a CKKS parameter set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CkksParams {
    /// Ring degree N (power of two). Slot count is N/2.
    pub n: usize,
    /// Bits of the base prime q0 (holds the final message + noise).
    pub q0_bits: u32,
    /// Bits of each scaling prime (the paper uses p = 33).
    pub scale_bits: u32,
    /// Multiplicative depth L: number of scaling primes.
    pub levels: usize,
    /// Bits of the special key-switching prime P.
    pub special_bits: u32,
    /// Allow parameter sets below 128-bit security (for tests/toy runs).
    pub allow_insecure: bool,
}

impl CkksParams {
    /// A small insecure parameter set for unit tests (fast keygen/ops).
    pub fn toy(levels: usize) -> Self {
        CkksParams {
            n: 1 << 11,
            q0_bits: 50,
            scale_bits: 33,
            levels,
            special_bits: 55,
            allow_insecure: true,
        }
    }

    /// Total log2 of the ciphertext modulus Q (excluding the special prime),
    /// which is the quantity the paper's Table 6 reports as `Q`.
    pub fn log_q(&self) -> u32 {
        self.q0_bits + self.scale_bits * self.levels as u32
    }

    /// Build the full precomputation context (primes, NTT tables, CRT data).
    pub fn build(&self) -> anyhow::Result<Arc<CkksContext>> {
        CkksContext::new(self.clone()).map(Arc::new)
    }
}

/// Shared, immutable context: primes, NTT tables, and CRT precomputations.
pub struct CkksContext {
    pub params: CkksParams,
    pub n: usize,
    /// q_0, q_1, ..., q_L  (q_0 first; rescale drops from the back).
    pub moduli: Vec<u64>,
    /// Special prime P for hybrid key switching.
    pub special: u64,
    /// NTT tables, one per modulus, same order as `moduli`.
    pub ntt: Vec<NttTable>,
    /// NTT table for the special prime.
    pub ntt_special: NttTable,
    /// Default encoding scale Δ = 2^scale_bits.
    pub scale: f64,
    /// `inv_last[m][j] = q_m^{-1} mod q_j`, for j < m (rescale).
    pub inv_last: Vec<Vec<u64>>,
    /// Shoup-precomputed `inv_last` (§Perf-6: rescale used to rebuild the
    /// `ShoupMul` per call, per limb — one 128-bit division each).
    pub inv_last_shoup: Vec<Vec<zq::ShoupMul>>,
    /// q_m mod q_j, for j < m (rescale centering correction).
    pub mod_last: Vec<Vec<u64>>,
    /// P^{-1} mod q_j (hybrid key-switch ModDown).
    pub p_inv: Vec<u64>,
    /// Shoup-precomputed `p_inv` (§Perf-6, same story for ModDown).
    pub p_inv_shoup: Vec<zq::ShoupMul>,
    /// P mod q_j.
    pub p_mod: Vec<u64>,
    /// Barrett reduction contexts, index-aligned with `moduli` plus the
    /// special prime as the last entry (DESIGN.md §Perf-1: removes 128-bit
    /// division from every pointwise product and key-switch digit).
    pub barrett: Vec<zq::Barrett>,
}

impl CkksContext {
    fn new(params: CkksParams) -> anyhow::Result<Self> {
        let n = params.n;
        anyhow::ensure!(n.is_power_of_two() && n >= 8, "N must be a power of two >= 8");
        anyhow::ensure!(params.levels >= 1, "need at least one level");
        if !params.allow_insecure {
            let total = params.log_q() + params.special_bits;
            anyhow::ensure!(
                super::security::is_secure_128(n, total),
                "params (N={n}, logQP={total}) below 128-bit security; \
                 set allow_insecure for toy runs"
            );
        }
        // distinct primes: q0, then `levels` scaling primes, then special.
        let q0 = zq::gen_ntt_primes(params.q0_bits, n, 1, &[])[0];
        let mut exclude = vec![q0];
        let scaling = zq::gen_ntt_primes(params.scale_bits, n, params.levels, &exclude);
        exclude.extend_from_slice(&scaling);
        let special = zq::gen_ntt_primes(params.special_bits, n, 1, &exclude)[0];

        let mut moduli = vec![q0];
        moduli.extend_from_slice(&scaling);

        let ntt: Vec<NttTable> = moduli.iter().map(|&q| NttTable::new(n, q)).collect();
        let ntt_special = NttTable::new(n, special);

        let k = moduli.len();
        let mut inv_last = vec![Vec::new(); k];
        let mut mod_last = vec![Vec::new(); k];
        for m in 0..k {
            for j in 0..m {
                inv_last[m].push(zq::inv_mod(moduli[m] % moduli[j], moduli[j]));
                mod_last[m].push(moduli[m] % moduli[j]);
            }
        }
        let inv_last_shoup = inv_last
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .map(|(j, &inv)| zq::ShoupMul::new(inv, moduli[j]))
                    .collect()
            })
            .collect();
        let p_inv: Vec<u64> = moduli.iter().map(|&q| zq::inv_mod(special % q, q)).collect();
        let p_inv_shoup = p_inv
            .iter()
            .zip(&moduli)
            .map(|(&inv, &q)| zq::ShoupMul::new(inv, q))
            .collect();
        let p_mod = moduli.iter().map(|&q| special % q).collect();
        let mut barrett: Vec<zq::Barrett> = moduli.iter().map(|&q| zq::Barrett::new(q)).collect();
        barrett.push(zq::Barrett::new(special));

        Ok(CkksContext {
            scale: 2f64.powi(params.scale_bits as i32),
            n,
            moduli,
            special,
            ntt,
            ntt_special,
            inv_last,
            inv_last_shoup,
            mod_last,
            p_inv,
            p_inv_shoup,
            p_mod,
            barrett,
            params,
        })
    }

    /// Number of slots (N/2).
    pub fn slots(&self) -> usize {
        self.n / 2
    }

    /// Max level index (a fresh ciphertext has `levels` rescales available,
    /// i.e. `levels + 1` RNS limbs).
    pub fn max_level(&self) -> usize {
        self.params.levels
    }

    /// NTT table for modulus index `j` (counting the special prime as the
    /// virtual index `self.moduli.len()`).
    pub fn ntt_for(&self, j: usize) -> &NttTable {
        if j < self.moduli.len() {
            &self.ntt[j]
        } else {
            &self.ntt_special
        }
    }

    /// Barrett context at modulus index `j` (special prime as last index).
    pub fn barrett_for(&self, j: usize) -> &zq::Barrett {
        &self.barrett[j.min(self.moduli.len())]
    }

    /// Modulus value at index `j` (special prime as the last virtual index).
    pub fn modulus(&self, j: usize) -> u64 {
        if j < self.moduli.len() {
            self.moduli[j]
        } else {
            self.special
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_toy_context_builds() {
        let ctx = CkksParams::toy(4).build().unwrap();
        assert_eq!(ctx.moduli.len(), 5);
        assert_eq!(ctx.slots(), 1024);
        // all primes distinct and NTT-friendly
        let mut all = ctx.moduli.clone();
        all.push(ctx.special);
        for &q in &all {
            assert!(zq::is_prime(q));
            assert_eq!(q % (2 * ctx.n as u64), 1);
        }
        let mut d = all.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), all.len());
    }

    #[test]
    fn test_shoup_tables_match_per_call_construction() {
        // the precomputed tables must be exactly what the kernels used to
        // build per call — the §Perf-6 bit-identity argument
        let ctx = CkksParams::toy(3).build().unwrap();
        for m in 0..ctx.moduli.len() {
            assert_eq!(ctx.inv_last_shoup[m].len(), ctx.inv_last[m].len());
            for j in 0..m {
                let per_call = zq::ShoupMul::new(ctx.inv_last[m][j], ctx.moduli[j]);
                assert_eq!(ctx.inv_last_shoup[m][j].w, per_call.w);
                assert_eq!(ctx.inv_last_shoup[m][j].w_shoup, per_call.w_shoup);
            }
        }
        for j in 0..ctx.moduli.len() {
            let per_call = zq::ShoupMul::new(ctx.p_inv[j], ctx.moduli[j]);
            assert_eq!(ctx.p_inv_shoup[j].w, per_call.w);
            assert_eq!(ctx.p_inv_shoup[j].w_shoup, per_call.w_shoup);
        }
    }

    #[test]
    fn test_insecure_params_rejected() {
        let p = CkksParams {
            allow_insecure: false,
            ..CkksParams::toy(8)
        };
        assert!(p.build().is_err(), "N=2^11 with 8 levels must fail 128-bit check");
    }

    #[test]
    fn test_log_q_matches_table6_row() {
        // paper row 6-STGCN-3: q0=47, p=33, L=14 -> Q=509
        let p = CkksParams {
            n: 1 << 15,
            q0_bits: 47,
            scale_bits: 33,
            levels: 14,
            special_bits: 60,
            allow_insecure: true,
        };
        assert_eq!(p.log_q(), 509);
    }
}
