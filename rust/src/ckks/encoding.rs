//! CKKS canonical-embedding encoder (DESIGN.md S6).
//!
//! Packs `N/2` complex (here: real) slots into one plaintext polynomial via
//! the special FFT over the 5-power rotation group (the HEAAN/SEAL layout):
//! slot `i` is the evaluation of the polynomial at `ζ^{5^i}` where `ζ` is a
//! primitive 2N-th root of unity. This layout makes the Galois automorphism
//! `x → x^{5^k}` act as a cyclic rotation of the slot vector — the `Rot(ct,k)`
//! operation the paper's AMA format relies on.

use super::params::CkksContext;
use super::poly::RnsPoly;

/// Minimal complex number (avoids an external dependency).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }
    #[inline]
    fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

/// A plaintext: an encoded polynomial (NTT form) plus scale and level shape.
#[derive(Clone, Debug)]
pub struct Plaintext {
    pub poly: RnsPoly,
    pub scale: f64,
}

/// Encoder precomputations for one ring degree.
pub struct Encoder {
    n: usize,
    /// 2N-th roots of unity e^{2πi j / 2N}, j in 0..2N.
    ksi: Vec<C64>,
    /// `rot_group[i] = 5^i mod 2N`, i in 0..N/2.
    rot_group: Vec<usize>,
}

fn bit_reverse_array(v: &mut [C64]) {
    let n = v.len();
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = i.reverse_bits() >> (usize::BITS - bits);
        if i < j {
            v.swap(i, j);
        }
    }
}

impl Encoder {
    pub fn new(n: usize) -> Self {
        let m = 2 * n;
        let ksi: Vec<C64> = (0..m)
            .map(|j| {
                let theta = 2.0 * std::f64::consts::PI * j as f64 / m as f64;
                C64::new(theta.cos(), theta.sin())
            })
            .collect();
        let mut rot_group = Vec::with_capacity(n / 2);
        let mut g = 1usize;
        for _ in 0..n / 2 {
            rot_group.push(g);
            g = (g * 5) % m;
        }
        Encoder { n, ksi, rot_group }
    }

    /// Forward special FFT: polynomial "unpacked halves" -> slot values.
    fn fft_special(&self, vals: &mut [C64]) {
        let size = vals.len();
        let m = 2 * self.n;
        bit_reverse_array(vals);
        let mut len = 2;
        while len <= size {
            let lenh = len >> 1;
            let lenq = len << 2;
            let mut i = 0;
            while i < size {
                for j in 0..lenh {
                    let idx = (self.rot_group[j] % lenq) * (m / lenq);
                    let u = vals[i + j];
                    let v = vals[i + j + lenh].mul(self.ksi[idx]);
                    vals[i + j] = u.add(v);
                    vals[i + j + lenh] = u.sub(v);
                }
                i += len;
            }
            len <<= 1;
        }
    }

    /// Inverse special FFT: slot values -> polynomial "unpacked halves".
    fn fft_special_inv(&self, vals: &mut [C64]) {
        let size = vals.len();
        let m = 2 * self.n;
        let mut len = size;
        while len >= 2 {
            let lenh = len >> 1;
            let lenq = len << 2;
            let mut i = 0;
            while i < size {
                for j in 0..lenh {
                    let idx = (lenq - (self.rot_group[j] % lenq)) * (m / lenq);
                    let u = vals[i + j].add(vals[i + j + lenh]);
                    let v = vals[i + j].sub(vals[i + j + lenh]).mul(self.ksi[idx]);
                    vals[i + j] = u;
                    vals[i + j + lenh] = v;
                }
                i += len;
            }
            len >>= 1;
        }
        bit_reverse_array(vals);
        let inv = 1.0 / size as f64;
        for v in vals.iter_mut() {
            v.re *= inv;
            v.im *= inv;
        }
    }

    /// Encode complex slots (length N/2) at `scale` into a plaintext with
    /// `nq` RNS limbs. Output polynomial is in NTT form, ready for PMult.
    pub fn encode_complex(
        &self,
        ctx: &CkksContext,
        slots: &[C64],
        scale: f64,
        nq: usize,
    ) -> Plaintext {
        let half = self.n / 2;
        assert!(slots.len() <= half, "too many slots");
        let mut vals = vec![C64::default(); half];
        vals[..slots.len()].copy_from_slice(slots);
        self.fft_special_inv(&mut vals);
        let mut coeffs = vec![0i128; self.n];
        for i in 0..half {
            coeffs[i] = (vals[i].re * scale).round() as i128;
            coeffs[i + half] = (vals[i].im * scale).round() as i128;
        }
        let mut poly = RnsPoly::from_signed_coeffs_i128(ctx, &coeffs, nq);
        poly.ntt_forward(ctx);
        Plaintext { poly, scale }
    }

    /// Encode real slots at `scale`.
    pub fn encode(&self, ctx: &CkksContext, slots: &[f64], scale: f64, nq: usize) -> Plaintext {
        let c: Vec<C64> = slots.iter().map(|&x| C64::new(x, 0.0)).collect();
        self.encode_complex(ctx, &c, scale, nq)
    }

    /// Decode a plaintext polynomial (any form) back to complex slots.
    pub fn decode_complex(&self, ctx: &CkksContext, pt: &Plaintext) -> Vec<C64> {
        let mut poly = pt.poly.clone();
        if poly.is_ntt {
            poly.ntt_inverse(ctx);
        }
        let coeffs = poly.to_signed_coeffs_i128(ctx);
        let half = self.n / 2;
        let inv_scale = 1.0 / pt.scale;
        let mut vals: Vec<C64> = (0..half)
            .map(|i| {
                C64::new(
                    coeffs[i] as f64 * inv_scale,
                    coeffs[i + half] as f64 * inv_scale,
                )
            })
            .collect();
        self.fft_special(&mut vals);
        vals
    }

    /// Decode real slots (imaginary parts discarded).
    pub fn decode(&self, ctx: &CkksContext, pt: &Plaintext) -> Vec<f64> {
        self.decode_complex(ctx, pt).into_iter().map(|c| c.re).collect()
    }

    /// Galois element g = 5^k mod 2N whose automorphism rotates the slot
    /// vector left by `k` positions.
    pub fn rotation_galois_element(&self, k: usize) -> usize {
        let m = 2 * self.n;
        let half = self.n / 2;
        let k = k % half;
        // 5^k mod 2N
        let mut g = 1usize;
        for _ in 0..k {
            g = (g * 5) % m;
        }
        g
    }

    /// Galois element for complex conjugation of all slots.
    pub fn conjugation_galois_element(&self) -> usize {
        2 * self.n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::params::CkksParams;

    fn setup() -> (std::sync::Arc<crate::ckks::params::CkksContext>, Encoder) {
        let mut p = CkksParams::toy(3);
        p.n = 1 << 8;
        let ctx = p.build().unwrap();
        let enc = Encoder::new(ctx.n);
        (ctx, enc)
    }

    #[test]
    fn test_encode_decode_roundtrip() {
        let (ctx, enc) = setup();
        let half = ctx.slots();
        let slots: Vec<f64> = (0..half).map(|i| (i as f64 / half as f64) * 2.0 - 1.0).collect();
        let pt = enc.encode(&ctx, &slots, ctx.scale, 4);
        let back = enc.decode(&ctx, &pt);
        for (a, b) in slots.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn test_encode_decode_complex_roundtrip() {
        let (ctx, enc) = setup();
        let half = ctx.slots();
        let slots: Vec<C64> = (0..half)
            .map(|i| C64::new((i as f64).sin(), (i as f64).cos()))
            .collect();
        let pt = enc.encode_complex(&ctx, &slots, ctx.scale, 2);
        let back = enc.decode_complex(&ctx, &pt);
        for (a, b) in slots.iter().zip(&back) {
            assert!((a.re - b.re).abs() < 1e-6 && (a.im - b.im).abs() < 1e-6);
        }
    }

    #[test]
    fn test_poly_mult_is_slotwise_product() {
        // the defining homomorphism: negacyclic poly product == slot product
        let (ctx, enc) = setup();
        let half = ctx.slots();
        let a: Vec<f64> = (0..half).map(|i| ((i * 7 % 13) as f64 - 6.0) / 6.0).collect();
        let b: Vec<f64> = (0..half).map(|i| ((i * 5 % 11) as f64 - 5.0) / 5.0).collect();
        let pa = enc.encode(&ctx, &a, ctx.scale, 3);
        let pb = enc.encode(&ctx, &b, ctx.scale, 3);
        let prod = Plaintext {
            poly: pa.poly.mul(&ctx, &pb.poly),
            scale: pa.scale * pb.scale,
        };
        let got = enc.decode(&ctx, &prod);
        for i in 0..half {
            assert!(
                (got[i] - a[i] * b[i]).abs() < 1e-5,
                "slot {i}: {} vs {}",
                got[i],
                a[i] * b[i]
            );
        }
    }

    #[test]
    fn test_automorphism_rotates_slots() {
        let (ctx, enc) = setup();
        let half = ctx.slots();
        let slots: Vec<f64> = (0..half).map(|i| i as f64).collect();
        let pt = enc.encode(&ctx, &slots, ctx.scale, 2);
        for k in [1usize, 2, 7, half - 1] {
            let g = enc.rotation_galois_element(k);
            let mut poly = pt.poly.clone();
            poly.ntt_inverse(&ctx);
            let rotated = poly.automorphism(&ctx, g);
            let rpt = Plaintext {
                poly: rotated,
                scale: pt.scale,
            };
            let got = enc.decode(&ctx, &rpt);
            for i in 0..half {
                let want = slots[(i + k) % half];
                assert!(
                    (got[i] - want).abs() < 1e-5,
                    "k={k} slot {i}: {} vs {want}",
                    got[i]
                );
            }
        }
    }

    #[test]
    fn test_conjugation_element() {
        let (ctx, enc) = setup();
        let half = ctx.slots();
        let slots: Vec<C64> = (0..half).map(|i| C64::new(i as f64, (i as f64) * 0.5)).collect();
        let pt = enc.encode_complex(&ctx, &slots, ctx.scale, 2);
        let mut poly = pt.poly.clone();
        poly.ntt_inverse(&ctx);
        let conj = poly.automorphism(&ctx, enc.conjugation_galois_element());
        let got = enc.decode_complex(
            &ctx,
            &Plaintext {
                poly: conj,
                scale: pt.scale,
            },
        );
        for i in 0..half {
            assert!((got[i].re - slots[i].re).abs() < 1e-5);
            assert!((got[i].im + slots[i].im).abs() < 1e-5);
        }
    }

    #[test]
    fn test_scale_drift_tolerance() {
        // encoding at a non-power-of-two scale (as after rescale) still works
        let (ctx, enc) = setup();
        let half = ctx.slots();
        let slots: Vec<f64> = (0..half).map(|i| (i % 10) as f64 / 10.0).collect();
        let odd_scale = ctx.scale * 1.0173; // mimics Δ²/q_l drift
        let pt = enc.encode(&ctx, &slots, odd_scale, 2);
        let back = enc.decode(&ctx, &pt);
        for (a, b) in slots.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
