//! The homomorphic evaluator (DESIGN.md S7): Add, CMult(+relin), PMult,
//! Rot, Rescale, conjugation and level management — the exact operation
//! algebra of the paper's Section 2, with per-op counters feeding the
//! cost model (DESIGN.md S12) so every paper table can be regenerated
//! from real operation counts.

use super::arena;
use super::encoding::{Encoder, Plaintext};
use super::encrypt::Ciphertext;
use super::keys::{EvalKeys, KeySwitchKey};
use super::params::CkksContext;
use super::poly::{par_limbs, RnsPoly};
use super::zq;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Ablation toggle (bench mode `--kernels`): `true` (default) runs the
/// fused lazy-reduction key-switch inner product (§Perf-5); `false`
/// restores the pre-campaign eager per-element Barrett + modular-add
/// path. Both are bit-identical — the fused path reduces the *sum* of
/// full 128-bit digit products once per output word, and
/// `Σ (dᵢ·kᵢ mod q) mod q == (Σ dᵢ·kᵢ) mod q`.
static FUSED_KEYSWITCH: AtomicBool = AtomicBool::new(true);

/// Select the fused (default) or eager key-switch inner product.
pub fn set_fused_keyswitch(fused: bool) {
    FUSED_KEYSWITCH.store(fused, Ordering::Relaxed);
}

/// Whether key switching currently uses the fused inner product.
pub fn fused_keyswitch() -> bool {
    FUSED_KEYSWITCH.load(Ordering::Relaxed)
}

/// The fused inner product's overflow headroom: RNS primes are ≤ 61 bits
/// (`zq::gen_ntt_primes` asserts it), so each digit product is < 2^122
/// and up to 2^6 = 64 of them sum without overflowing a u128. Every real
/// chain has far fewer digits (nq ≤ levels + 1).
const MAX_FUSED_DIGITS: usize = 64;

/// Accumulate `digit × key` into both 128-bit accumulators, walking each
/// digit limb **once** (§Perf-5: the eager path loaded every digit word
/// twice — once per accumulator — and paid a Barrett reduction plus a
/// modular add per word per digit). The key polynomials are stored over
/// the full `Q ∪ {P}` basis, so working-set limb `idx` maps to key limb
/// `idx` for the Q part and to the key's trailing special limb otherwise —
/// indexed directly instead of materializing the `subset()` clones the
/// eager path takes.
fn fused_acc(
    digit: &RnsPoly,
    kb: &RnsPoly,
    ka: &RnsPoly,
    nq: usize,
    acc0: &mut [Vec<u128>],
    acc1: &mut [Vec<u128>],
) {
    debug_assert!(digit.is_ntt && kb.is_ntt && ka.is_ntt);
    debug_assert!(digit.has_special && kb.has_special && ka.has_special);
    let mut pairs: Vec<(&mut Vec<u128>, &mut Vec<u128>)> =
        acc0.iter_mut().zip(acc1.iter_mut()).collect();
    par_limbs(&mut pairs, |idx, (a0, a1)| {
        let kidx = if idx < nq { idx } else { kb.nq };
        let dv = &digit.limbs[idx];
        let bv = &kb.limbs[kidx];
        let av = &ka.limbs[kidx];
        for t in 0..dv.len() {
            let x = dv[t] as u128;
            a0[t] += x * bv[t] as u128;
            a1[t] += x * av[t] as u128;
        }
    });
}

/// Reduce a fused accumulator **once** per output word into an NTT-form
/// extended-basis polynomial: one `Barrett::reduce_u128` per word total,
/// where the eager path paid one reduction *plus* a modular add per word
/// per digit. `reduce_u128` is exact and canonical for any u128 input
/// (its quotient underestimates the true one by < 2; one conditional
/// subtract finishes), so the result equals the eager chain bit for bit.
fn reduce_acc(ctx: &CkksContext, acc: &[Vec<u128>], nq: usize) -> RnsPoly {
    let special = ctx.moduli.len();
    let mut out = RnsPoly::scratch(ctx, nq, true, true);
    par_limbs(&mut out.limbs, |idx, dst| {
        let br = ctx.barrett_for(if idx < nq { idx } else { special });
        for (d, &v) in dst.iter_mut().zip(&acc[idx]) {
            *d = br.reduce_u128(v);
        }
    });
    out
}

/// Generates the counter registry once from a single field list, so
/// `OpCounters`, its `OpCounts` snapshot, `snapshot()`, `reset()` and the
/// array views can never drift out of sync when a counter is added.
macro_rules! define_op_counters {
    ($($(#[$doc:meta])* $field:ident),* $(,)?) => {
        /// Homomorphic-op counters, keyed the way the paper's Table 7
        /// reports them (plus serving-path counters). Fields are defined by
        /// the `define_op_counters!` list; add new counters there only.
        #[derive(Default, Debug)]
        pub struct OpCounters {
            $($(#[$doc])* pub $field: AtomicU64,)*
        }

        /// A plain-old-data snapshot of the counters.
        #[derive(Clone, Copy, Debug, Default, PartialEq)]
        pub struct OpCounts {
            $($(#[$doc])* pub $field: u64,)*
        }

        impl OpCounters {
            pub fn snapshot(&self) -> OpCounts {
                OpCounts {
                    $($field: self.$field.load(Ordering::Relaxed),)*
                }
            }

            pub fn reset(&self) {
                $(self.$field.store(0, Ordering::Relaxed);)*
            }
        }

        impl OpCounts {
            /// Field names, in declaration order (aligned with
            /// [`OpCounts::to_array`]).
            pub fn field_names() -> &'static [&'static str] {
                &[$(stringify!($field)),*]
            }

            /// All counters as an array in declaration order (plan
            /// serialization, diffing).
            pub fn to_array(&self) -> Vec<u64> {
                vec![$(self.$field),*]
            }

            /// Inverse of [`OpCounts::to_array`]; `None` on length mismatch.
            pub fn from_array(values: &[u64]) -> Option<OpCounts> {
                if values.len() != Self::field_names().len() {
                    return None;
                }
                let mut it = values.iter().copied();
                Some(OpCounts {
                    $($field: it.next()?,)*
                })
            }
        }
    };
}

define_op_counters!(
    add,
    pmult,
    cmult,
    rot,
    rescale,
    /// Σ over ops of the RNS limb count at which the op ran (cost ∝ limbs).
    add_limbs,
    pmult_limbs,
    cmult_limbs,
    rot_limbs,
    rescale_limbs,
    /// Σ limbs² for the key-switching ops (their cost is quadratic in the
    /// limb count: digits × extended-basis NTT work).
    cmult_limbs_sq,
    rot_limbs_sq,
    /// Serving-path: requests answered from a cached compiled `HePlan`
    /// (he_infer::exec; DESIGN.md S14).
    plan_cache_hit,
    /// Serving-path: plan compilations forced by a cache miss.
    plan_cache_miss,
    /// Tasks executed by the plan executor's wavefront worker pool
    /// (bumped only when executing with >1 thread).
    pool_tasks,
    /// Rotation-path RNS digit decompositions performed: one per plain
    /// `Rot`, one per hoisted `RotGroup` — the quantity Halevi–Shoup
    /// hoisting shares (DESIGN.md S17). Relinearization decompositions
    /// are costed by `cmult_limbs_sq`, not here.
    ks_decomp,
    /// Σ limbs² per rotation-path digit decomposition (spread + forward
    /// NTT work of one full decomposition is quadratic in the limb count).
    ks_decomp_limbs_sq,
    /// Hoisted rotation groups executed (0 on unoptimized plans).
    rot_group,
    /// Client-aided refresh cut points (`HeOp::Refresh`, DESIGN.md S21):
    /// level resets bought with a masked round trip instead of chain
    /// budget. Not HE work on the server — costed separately as round
    /// latency, so excluded from `cost_fields`. **Append-only list**: the
    /// plan-text version window stores arity prefixes of this array.
    refresh,
);

impl OpCounts {
    pub fn total_ops(&self) -> u64 {
        self.add + self.pmult + self.cmult + self.rot
    }

    /// The cost-bearing counters the optimizer must never increase
    /// (DESIGN.md S17): every HE-work field, excluding the serving-path
    /// bookkeeping (`plan_cache_*`, `pool_tasks`) and the structural
    /// `rot_group` tally (grouping *adds* groups while strictly removing
    /// decomposition work — the gate below is over work, not structure).
    pub fn cost_fields(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("add", self.add),
            ("pmult", self.pmult),
            ("cmult", self.cmult),
            ("rot", self.rot),
            ("rescale", self.rescale),
            ("add_limbs", self.add_limbs),
            ("pmult_limbs", self.pmult_limbs),
            ("cmult_limbs", self.cmult_limbs),
            ("rot_limbs", self.rot_limbs),
            ("rescale_limbs", self.rescale_limbs),
            ("cmult_limbs_sq", self.cmult_limbs_sq),
            ("rot_limbs_sq", self.rot_limbs_sq),
            ("ks_decomp", self.ks_decomp),
            ("ks_decomp_limbs_sq", self.ks_decomp_limbs_sq),
        ]
    }
}

/// The evaluator. `Clone`-cheap via `Arc`s; thread-safe counters.
pub struct Evaluator {
    pub ctx: Arc<CkksContext>,
    pub keys: Arc<EvalKeys>,
    pub counters: OpCounters,
    /// Relative scale mismatch tolerated by `add` before erroring.
    pub scale_rtol: f64,
    /// Cached NTT-domain automorphism permutations per Galois element.
    auto_perms: Mutex<HashMap<usize, Arc<Vec<usize>>>>,
}

impl Evaluator {
    pub fn new(ctx: Arc<CkksContext>, keys: Arc<EvalKeys>) -> Self {
        Evaluator {
            ctx,
            keys,
            counters: OpCounters::default(),
            scale_rtol: 1e-3,
            auto_perms: Mutex::new(HashMap::new()),
        }
    }

    // ---------------------------------------------------------------- add

    /// Homomorphic addition. Levels are aligned by dropping limbs; scales
    /// must agree to within `scale_rtol`.
    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let (a, b) = self.align(a, b);
        assert!(
            (a.scale - b.scale).abs() / a.scale < self.scale_rtol,
            "scale mismatch in add: {} vs {}",
            a.scale,
            b.scale
        );
        let mut out = a.clone();
        out.c0.add_assign(&self.ctx, &b.c0);
        out.c1.add_assign(&self.ctx, &b.c1);
        self.counters.add.fetch_add(1, Ordering::Relaxed);
        self.counters
            .add_limbs
            .fetch_add(out.c0.nq as u64, Ordering::Relaxed);
        out
    }

    pub fn sub(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let (a, b) = self.align(a, b);
        let mut out = a.clone();
        out.c0.sub_assign(&self.ctx, &b.c0);
        out.c1.sub_assign(&self.ctx, &b.c1);
        self.counters.add.fetch_add(1, Ordering::Relaxed);
        self.counters
            .add_limbs
            .fetch_add(out.c0.nq as u64, Ordering::Relaxed);
        out
    }

    pub fn negate(&self, a: &Ciphertext) -> Ciphertext {
        let mut out = a.clone();
        out.c0.neg_assign(&self.ctx);
        out.c1.neg_assign(&self.ctx);
        out
    }

    /// ct + pt (plaintext must share scale and level shape).
    pub fn add_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        let mut out = a.clone();
        let p = if pt.poly.nq == out.c0.nq {
            pt.poly.clone()
        } else {
            let mut p = pt.poly.clone();
            assert!(p.nq >= out.c0.nq, "plaintext encoded at too low a level");
            p.truncate_to(out.c0.nq);
            p
        };
        assert!(
            (a.scale - pt.scale).abs() / a.scale < self.scale_rtol,
            "scale mismatch in add_plain"
        );
        out.c0.add_assign(&self.ctx, &p);
        self.counters.add.fetch_add(1, Ordering::Relaxed);
        self.counters
            .add_limbs
            .fetch_add(out.c0.nq as u64, Ordering::Relaxed);
        out
    }

    // -------------------------------------------------------------- pmult

    /// Plaintext multiplication (no relinearization, no key material).
    /// Result scale = ct.scale * pt.scale; caller typically rescales.
    pub fn mul_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        let nq = a.c0.nq;
        let p = if pt.poly.nq == nq {
            pt.poly.clone()
        } else {
            let mut p = pt.poly.clone();
            assert!(p.nq >= nq);
            p.truncate_to(nq);
            p
        };
        let mut out = a.clone();
        out.c0.mul_assign(&self.ctx, &p);
        out.c1.mul_assign(&self.ctx, &p);
        out.scale = a.scale * pt.scale;
        self.counters.pmult.fetch_add(1, Ordering::Relaxed);
        self.counters
            .pmult_limbs
            .fetch_add(nq as u64, Ordering::Relaxed);
        out
    }

    /// Multiply by a scalar constant, encoded on the fly at scale Δ.
    pub fn mul_scalar(&self, enc: &Encoder, a: &Ciphertext, v: f64) -> Ciphertext {
        let slots = vec![v; self.ctx.slots()];
        let pt = enc.encode(&self.ctx, &slots, self.ctx.scale, a.c0.nq);
        self.mul_plain(a, &pt)
    }

    // -------------------------------------------------------------- cmult

    /// Ciphertext-ciphertext multiplication with relinearization.
    /// Result scale is the product; caller typically rescales.
    pub fn mul(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let (a, b) = self.align(a, b);
        let ctx = &self.ctx;
        let d0 = a.c0.mul(ctx, &b.c0);
        let mut d1 = a.c0.mul(ctx, &b.c1);
        d1.add_assign(ctx, &a.c1.mul(ctx, &b.c0));
        let d2 = a.c1.mul(ctx, &b.c1);

        // relinearize d2: key-switch from s² to s
        let (u0, u1) = self.key_switch(&d2, &self.keys.relin);
        let mut c0 = d0;
        c0.add_assign(ctx, &u0);
        let mut c1 = d1;
        c1.add_assign(ctx, &u1);

        self.counters.cmult.fetch_add(1, Ordering::Relaxed);
        self.counters
            .cmult_limbs
            .fetch_add(c0.nq as u64, Ordering::Relaxed);
        self.counters
            .cmult_limbs_sq
            .fetch_add((c0.nq * c0.nq) as u64, Ordering::Relaxed);
        Ciphertext {
            c0,
            c1,
            scale: a.scale * b.scale,
        }
    }

    /// Homomorphic square (same cost shape as `mul`).
    pub fn square(&self, a: &Ciphertext) -> Ciphertext {
        self.mul(a, a)
    }

    // ---------------------------------------------------------------- rot

    /// Rotate slot vector left by `k` (paper's `Rot(ct, k)`), via the Galois
    /// automorphism x → x^{5^k} followed by a key switch.
    pub fn rotate(&self, enc: &Encoder, a: &Ciphertext, k: usize) -> Ciphertext {
        let half = self.ctx.slots();
        let k = k % half;
        if k == 0 {
            return a.clone();
        }
        let g = enc.rotation_galois_element(k);
        self.apply_galois(a, g)
    }

    /// Complex-conjugate every slot.
    pub fn conjugate(&self, enc: &Encoder, a: &Ciphertext) -> Ciphertext {
        self.apply_galois(a, enc.conjugation_galois_element())
    }

    /// Cached NTT-domain automorphism permutation for Galois element `g`.
    fn auto_perm(&self, g: usize) -> Arc<Vec<usize>> {
        let mut cache = self.auto_perms.lock().unwrap();
        cache
            .entry(g)
            .or_insert_with(|| {
                Arc::new(super::poly::ntt_automorphism_permutation(self.ctx.n, g))
            })
            .clone()
    }

    fn apply_galois(&self, a: &Ciphertext, g: usize) -> Ciphertext {
        let ctx = &self.ctx;
        let key = self
            .keys
            .galois
            .get(&g)
            .unwrap_or_else(|| panic!("no galois key for element {g}"));
        // c0: permute directly in NTT domain (no NTT round-trip, §Perf)
        let perm = self.auto_perm(g);
        let tc0 = a.c0.automorphism_ntt(&perm);
        // c1: key switching needs coefficient-form digits
        let mut c1 = a.c1.clone();
        c1.ntt_inverse(ctx);
        let tc1 = c1.automorphism(ctx, g);
        let (u0, u1) = self.key_switch_coeff(&tc1, key);
        let mut r0 = tc0;
        r0.add_assign(ctx, &u0);
        self.counters.rot.fetch_add(1, Ordering::Relaxed);
        self.counters
            .rot_limbs
            .fetch_add(r0.nq as u64, Ordering::Relaxed);
        self.counters
            .rot_limbs_sq
            .fetch_add((r0.nq * r0.nq) as u64, Ordering::Relaxed);
        self.counters.ks_decomp.fetch_add(1, Ordering::Relaxed);
        self.counters
            .ks_decomp_limbs_sq
            .fetch_add((r0.nq * r0.nq) as u64, Ordering::Relaxed);
        Ciphertext {
            c0: r0,
            c1: u1,
            scale: a.scale,
        }
    }

    /// Hoisted rotation group (Halevi–Shoup; DESIGN.md S17): rotate `a`
    /// by every step in `ks` while performing the RNS digit decomposition
    /// of `c1` **once** for the whole group, instead of once per step.
    ///
    /// Bit-identity with per-step [`Evaluator::rotate`] rests on two
    /// exact commutations. (1) The centered digit lift in
    /// [`Evaluator::ks_digit`] commutes with the Galois automorphism's
    /// per-limb negation — `spread(−r mod q_i) = −spread(r) mod q_j`
    /// coefficient-for-coefficient — so the automorphism of a decomposed
    /// digit *is* the digit of the automorphed polynomial. (2) Applying
    /// the automorphism in NTT form is a pure slot permutation, so
    /// `perm_g(NTT(p)) = NTT(τ_g(p))` exactly. Everything downstream
    /// (mul_acc order, ModDown) is the same integer arithmetic in the
    /// same order as the per-step path, hence identical output bits —
    /// the property `rust/tests/property_suite.rs` and the eval unit
    /// tests pin down.
    ///
    /// Counter semantics: each produced rotation tallies as a `rot`
    /// (unchanged vs the per-step path); the shared decomposition tallies
    /// one `ks_decomp` for the whole group (vs one per step), plus one
    /// `rot_group`.
    pub fn rotate_group(&self, enc: &Encoder, a: &Ciphertext, ks: &[usize]) -> Vec<Ciphertext> {
        let ctx = &self.ctx;
        let half = ctx.slots();
        assert!(!ks.is_empty(), "rotate_group needs at least one step");
        let nq = a.c0.nq;
        // shared part: c1 to coefficient form once
        let mut c1 = a.c1.clone();
        c1.ntt_inverse(ctx);
        // one lane per step: (perm, key)
        let lanes: Vec<(Arc<Vec<usize>>, &KeySwitchKey)> = ks
            .iter()
            .map(|&k| {
                let k = k % half;
                assert!(k > 0, "rotate_group: rotation by 0 must be elided by the caller");
                let g = enc.rotation_galois_element(k);
                let key = self
                    .keys
                    .galois
                    .get(&g)
                    .unwrap_or_else(|| panic!("no galois key for element {g}"));
                (self.auto_perm(g), key)
            })
            .collect();
        let switched = if fused_keyswitch() && nq <= MAX_FUSED_DIGITS {
            self.rotate_group_switch_fused(&c1, &lanes, nq)
        } else {
            self.rotate_group_switch_eager(&c1, &lanes, nq)
        };
        let mut out = Vec::with_capacity(lanes.len());
        for ((perm, _key), (u0, u1)) in lanes.iter().zip(switched) {
            let mut r0 = a.c0.automorphism_ntt(perm);
            r0.add_assign(ctx, &u0);
            u0.recycle();
            self.counters.rot.fetch_add(1, Ordering::Relaxed);
            self.counters
                .rot_limbs
                .fetch_add(r0.nq as u64, Ordering::Relaxed);
            self.counters
                .rot_limbs_sq
                .fetch_add((r0.nq * r0.nq) as u64, Ordering::Relaxed);
            out.push(Ciphertext {
                c0: r0,
                c1: u1,
                scale: a.scale,
            });
        }
        self.counters.rot_group.fetch_add(1, Ordering::Relaxed);
        self.counters.ks_decomp.fetch_add(1, Ordering::Relaxed);
        self.counters
            .ks_decomp_limbs_sq
            .fetch_add((nq * nq) as u64, Ordering::Relaxed);
        out
    }

    /// Per-lane key-switch outputs for a hoisted rotation group, fused
    /// inner product (§Perf-5): each digit is spread + NTT'd once, the
    /// permuted digit accumulates into per-lane 128-bit accumulators —
    /// one reduction per output word per lane at the end, no key subset
    /// clones, all transients recycled through the arena.
    fn rotate_group_switch_fused(
        &self,
        c1: &RnsPoly,
        lanes: &[(Arc<Vec<usize>>, &KeySwitchKey)],
        nq: usize,
    ) -> Vec<(RnsPoly, RnsPoly)> {
        let ctx = &self.ctx;
        debug_assert!(nq <= MAX_FUSED_DIGITS);
        let mut accs: Vec<(Vec<Vec<u128>>, Vec<Vec<u128>>)> = lanes
            .iter()
            .map(|_| (arena::take_acc(ctx.n, nq + 1), arena::take_acc(ctx.n, nq + 1)))
            .collect();
        // decompose-once: each digit is spread + NTT'd a single time, then
        // permuted per lane (only one digit is live at a time)
        for i in 0..nq {
            let mut digit = self.ks_digit(c1, i);
            digit.ntt_forward(ctx);
            for ((perm, key), (acc0, acc1)) in lanes.iter().zip(accs.iter_mut()) {
                let td = digit.automorphism_ntt(perm);
                fused_acc(&td, &key.digits[i].b, &key.digits[i].a, nq, acc0, acc1);
                td.recycle();
            }
            digit.recycle();
        }
        accs.into_iter()
            .map(|(acc0, acc1)| {
                let mut s0 = reduce_acc(ctx, &acc0, nq);
                let mut s1 = reduce_acc(ctx, &acc1, nq);
                arena::recycle_acc(acc0);
                arena::recycle_acc(acc1);
                s0.ntt_inverse(ctx);
                s1.ntt_inverse(ctx);
                let mut u0 = self.mod_down(&s0);
                let mut u1 = self.mod_down(&s1);
                s0.recycle();
                s1.recycle();
                u0.ntt_forward(ctx);
                u1.ntt_forward(ctx);
                (u0, u1)
            })
            .collect()
    }

    /// The pre-campaign eager group path, kept verbatim as the
    /// `--kernels` ablation baseline (subset clones + `mul_acc` per
    /// digit per lane).
    fn rotate_group_switch_eager(
        &self,
        c1: &RnsPoly,
        lanes: &[(Arc<Vec<usize>>, &KeySwitchKey)],
        nq: usize,
    ) -> Vec<(RnsPoly, RnsPoly)> {
        let ctx = &self.ctx;
        let mut accs: Vec<(RnsPoly, RnsPoly)> = lanes
            .iter()
            .map(|_| {
                (
                    RnsPoly::zero(ctx, nq, true, true),
                    RnsPoly::zero(ctx, nq, true, true),
                )
            })
            .collect();
        for i in 0..nq {
            let mut digit = self.ks_digit(c1, i);
            digit.ntt_forward(ctx);
            for ((perm, key), (acc0, acc1)) in lanes.iter().zip(accs.iter_mut()) {
                let td = digit.automorphism_ntt(&perm[..]);
                let kb = key.digits[i].b.subset(nq, true);
                let ka = key.digits[i].a.subset(nq, true);
                acc0.mul_acc(ctx, &td, &kb);
                acc1.mul_acc(ctx, &td, &ka);
                td.recycle();
            }
            digit.recycle();
        }
        accs.into_iter()
            .map(|(mut acc0, mut acc1)| {
                acc0.ntt_inverse(ctx);
                acc1.ntt_inverse(ctx);
                let mut u0 = self.mod_down(&acc0);
                let mut u1 = self.mod_down(&acc1);
                u0.ntt_forward(ctx);
                u1.ntt_forward(ctx);
                (u0, u1)
            })
            .collect()
    }

    // ------------------------------------------------------------ rescale

    /// CKKS Rescale: divide by the last chain prime, dropping one level.
    pub fn rescale(&self, a: &Ciphertext) -> Ciphertext {
        let ctx = &self.ctx;
        assert!(a.c0.nq > 1, "no levels left to rescale");
        let q_last = ctx.moduli[a.c0.nq - 1] as f64;
        let mut c0 = a.c0.clone();
        let mut c1 = a.c1.clone();
        c0.ntt_inverse(ctx);
        c1.ntt_inverse(ctx);
        c0.rescale_last(ctx);
        c1.rescale_last(ctx);
        c0.ntt_forward(ctx);
        c1.ntt_forward(ctx);
        self.counters.rescale.fetch_add(1, Ordering::Relaxed);
        self.counters
            .rescale_limbs
            .fetch_add(c0.nq as u64, Ordering::Relaxed);
        Ciphertext {
            c0,
            c1,
            scale: a.scale / q_last,
        }
    }

    /// Drop limbs without rescaling (modulus switch), aligning to `level`.
    pub fn mod_drop_to_level(&self, a: &Ciphertext, level: usize) -> Ciphertext {
        let target_nq = level + 1;
        assert!(target_nq <= a.c0.nq, "cannot raise level");
        if target_nq == a.c0.nq {
            return a.clone();
        }
        let mut out = a.clone();
        out.c0.truncate_to(target_nq);
        out.c1.truncate_to(target_nq);
        out
    }

    fn align(&self, a: &Ciphertext, b: &Ciphertext) -> (Ciphertext, Ciphertext) {
        if a.c0.nq == b.c0.nq {
            (a.clone(), b.clone())
        } else if a.c0.nq > b.c0.nq {
            (self.mod_drop_to_level(a, b.level()), b.clone())
        } else {
            (a.clone(), self.mod_drop_to_level(b, a.level()))
        }
    }

    // --------------------------------------------------------- key switch

    /// Hybrid key switch of an NTT-form degree-2 component.
    fn key_switch(&self, d: &RnsPoly, key: &KeySwitchKey) -> (RnsPoly, RnsPoly) {
        let mut dc = d.clone();
        dc.ntt_inverse(&self.ctx);
        self.key_switch_coeff(&dc, key)
    }

    /// Digit `i` of a coefficient-form polynomial `d`: the residues
    /// `[d]_{q_i}` lifted **centered** (values above `q_i/2` spread as
    /// `−(q_i − r)`) over the extended basis `Q_ℓ ∪ {P}`, per-target-limb
    /// independent → limb-parallel (DESIGN.md S14).
    ///
    /// The centered lift is what makes decomposition commute bit-exactly
    /// with the Galois automorphism — `spread_j(q_i − r) = −spread_j(r)
    /// mod q_j` for every target limb `j` (and `neg(0) = 0` on both
    /// sides) — the invariant [`Evaluator::rotate_group`]'s hoisting
    /// relies on. It also halves the digit magnitude bound, so key-switch
    /// noise only improves over the plain lift.
    fn ks_digit(&self, d: &RnsPoly, i: usize) -> RnsPoly {
        let ctx = &self.ctx;
        assert!(!d.is_ntt);
        let nq = d.nq;
        let n = ctx.n;
        let q_i = ctx.moduli[i];
        let half = q_i / 2;
        let src = &d.limbs[i];
        // scratch, not zero: both branches below write every word of every
        // limb, so the zero-fill was pure overwrite fodder (§Perf-6)
        let mut digit = RnsPoly::scratch(ctx, nq, true, false);
        super::poly::par_limbs(&mut digit.limbs, |j, dst| {
            if j == i {
                dst.copy_from_slice(src);
            } else {
                let m = if j < nq { j } else { ctx.moduli.len() };
                let q_j = ctx.modulus(m);
                let br = ctx.barrett_for(m);
                for t in 0..n {
                    let r = src[t];
                    dst[t] = if r > half {
                        zq::neg_mod(br.reduce_u64(q_i - r), q_j)
                    } else {
                        br.reduce_u64(r)
                    };
                }
            }
        });
        digit
    }

    /// Hybrid key switch, coefficient-form input. Returns NTT-form pair
    /// over the same Q limbs as the input. Dispatches between the fused
    /// lazy-reduction inner product (§Perf-5, default) and the eager
    /// pre-campaign path (ablation baseline; also the fallback past the
    /// u128 overflow headroom, which no real chain approaches).
    fn key_switch_coeff(&self, d: &RnsPoly, key: &KeySwitchKey) -> (RnsPoly, RnsPoly) {
        if fused_keyswitch() && d.nq <= MAX_FUSED_DIGITS {
            self.key_switch_coeff_fused(d, key)
        } else {
            self.key_switch_coeff_eager(d, key)
        }
    }

    /// Fused inner product: accumulate all `nq` digit products as full
    /// 128-bit integers per output word, reduce once per word, walking
    /// each NTT'd digit a single time for both accumulators and indexing
    /// the key limbs in place (no `subset()` clones). Bit-identical to
    /// [`Evaluator::key_switch_coeff_eager`] because
    /// `Σ (dᵢ·kᵢ mod q) mod q == (Σ dᵢ·kᵢ) mod q` and both paths end
    /// canonical in `[0, q)`.
    fn key_switch_coeff_fused(&self, d: &RnsPoly, key: &KeySwitchKey) -> (RnsPoly, RnsPoly) {
        let ctx = &self.ctx;
        assert!(!d.is_ntt && !d.has_special);
        let nq = d.nq;
        debug_assert!(nq <= MAX_FUSED_DIGITS);
        let mut acc0 = arena::take_acc(ctx.n, nq + 1);
        let mut acc1 = arena::take_acc(ctx.n, nq + 1);
        for i in 0..nq {
            let mut digit = self.ks_digit(d, i);
            digit.ntt_forward(ctx);
            fused_acc(&digit, &key.digits[i].b, &key.digits[i].a, nq, &mut acc0, &mut acc1);
            digit.recycle();
        }
        let mut s0 = reduce_acc(ctx, &acc0, nq);
        let mut s1 = reduce_acc(ctx, &acc1, nq);
        arena::recycle_acc(acc0);
        arena::recycle_acc(acc1);
        // ModDown by P (divide by the special prime, rounding)
        s0.ntt_inverse(ctx);
        s1.ntt_inverse(ctx);
        let mut u0 = self.mod_down(&s0);
        let mut u1 = self.mod_down(&s1);
        s0.recycle();
        s1.recycle();
        u0.ntt_forward(ctx);
        u1.ntt_forward(ctx);
        (u0, u1)
    }

    /// The pre-campaign eager path, kept verbatim as the `--kernels`
    /// ablation baseline: per digit, clone key-limb subsets and
    /// `mul_acc` (Barrett reduce + modular add per word) into NTT-form
    /// accumulators.
    fn key_switch_coeff_eager(&self, d: &RnsPoly, key: &KeySwitchKey) -> (RnsPoly, RnsPoly) {
        let ctx = &self.ctx;
        assert!(!d.is_ntt && !d.has_special);
        let nq = d.nq;
        let mut acc0 = RnsPoly::zero(ctx, nq, true, true);
        let mut acc1 = RnsPoly::zero(ctx, nq, true, true);
        for i in 0..nq {
            let mut digit = self.ks_digit(d, i);
            digit.ntt_forward(ctx);
            let kb = key.digits[i].b.subset(nq, true);
            let ka = key.digits[i].a.subset(nq, true);
            acc0.mul_acc(ctx, &digit, &kb);
            acc1.mul_acc(ctx, &digit, &ka);
            digit.recycle();
        }
        // ModDown by P (divide by the special prime, rounding)
        acc0.ntt_inverse(ctx);
        acc1.ntt_inverse(ctx);
        let mut u0 = self.mod_down(&acc0);
        let mut u1 = self.mod_down(&acc1);
        u0.ntt_forward(ctx);
        u1.ntt_forward(ctx);
        (u0, u1)
    }

    /// Exact division by the special prime with centered rounding.
    /// Limb-parallel (target limbs are independent), with the `P^{-1}`
    /// Shoup constants precomputed in the context (§Perf-6: this used to
    /// pay a 128-bit division per limb per call) and a scratch-arena
    /// output (every word is written below).
    fn mod_down(&self, u: &RnsPoly) -> RnsPoly {
        let ctx = &self.ctx;
        assert!(!u.is_ntt && u.has_special);
        let nq = u.nq;
        let sp = &u.limbs[nq]; // residues mod P
        let p = ctx.special;
        let half = p / 2;
        let mut out = RnsPoly::scratch(ctx, nq, false, false);
        par_limbs(&mut out.limbs, |j, dst| {
            let q_j = ctx.moduli[j];
            let p_mod = ctx.p_mod[j];
            let p_inv = &ctx.p_inv_shoup[j];
            let br = ctx.barrett_for(j);
            let src = &u.limbs[j];
            for t in 0..ctx.n {
                let r = sp[t];
                let mut v = zq::sub_mod(src[t], br.reduce_u64(r), q_j);
                if r > half {
                    v = zq::add_mod(v, p_mod, q_j);
                }
                dst[t] = p_inv.mul(v, q_j);
            }
        });
        out
    }
}

/// Generate all evaluation keys for a set of rotation steps.
pub fn build_eval_keys(
    ctx: &Arc<CkksContext>,
    enc: &Encoder,
    sk: &super::keys::SecretKey,
    rotation_steps: &[usize],
    with_conjugation: bool,
    rng: &mut crate::util::Rng,
) -> EvalKeys {
    let relin = super::keys::keygen_relin(ctx, sk, rng);
    let mut galois = HashMap::new();
    for &k in rotation_steps {
        let g = enc.rotation_galois_element(k);
        galois
            .entry(g)
            .or_insert_with(|| super::keys::keygen_galois(ctx, sk, g, rng));
    }
    if with_conjugation {
        let g = enc.conjugation_galois_element();
        galois
            .entry(g)
            .or_insert_with(|| super::keys::keygen_galois(ctx, sk, g, rng));
    }
    EvalKeys { relin, galois }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Encoder;
    use crate::ckks::encrypt::{decrypt, encrypt};
    use crate::ckks::keys::{keygen_public, keygen_secret};
    use crate::ckks::params::CkksParams;

    struct Fixture {
        ctx: Arc<CkksContext>,
        enc: Encoder,
        sk: crate::ckks::keys::SecretKey,
        pk: crate::ckks::keys::PublicKey,
        ev: Evaluator,
        rng: crate::util::Rng,
    }

    fn fixture(levels: usize, log_n: u32, rots: &[usize]) -> Fixture {
        let mut p = CkksParams::toy(levels);
        p.n = 1 << log_n;
        let ctx = p.build().unwrap();
        let enc = Encoder::new(ctx.n);
        let mut rng = crate::util::Rng::seed_from_u64(99);
        let sk = keygen_secret(&ctx, &mut rng);
        let pk = keygen_public(&ctx, &sk, &mut rng);
        let keys = Arc::new(build_eval_keys(&ctx, &enc, &sk, rots, false, &mut rng));
        let ev = Evaluator::new(ctx.clone(), keys);
        Fixture {
            ctx,
            enc,
            sk,
            pk,
            ev,
            rng,
        }
    }

    fn enc_vec(f: &mut Fixture, v: &[f64]) -> Ciphertext {
        let pt = f.enc.encode(&f.ctx, v, f.ctx.scale, f.ctx.max_level() + 1);
        encrypt(&f.ctx, &f.pk, &pt, &mut f.rng)
    }

    fn dec_vec(f: &Fixture, ct: &Ciphertext) -> Vec<f64> {
        f.enc.decode(&f.ctx, &decrypt(&f.ctx, &f.sk, ct))
    }

    #[test]
    fn test_cmult_relin_rescale() {
        let mut f = fixture(3, 9, &[]);
        let half = f.ctx.slots();
        let a: Vec<f64> = (0..half).map(|i| ((i % 7) as f64 - 3.0) / 3.0).collect();
        let b: Vec<f64> = (0..half).map(|i| ((i % 5) as f64 - 2.0) / 2.0).collect();
        let (ca, cb) = (enc_vec(&mut f, &a), enc_vec(&mut f, &b));
        let prod = f.ev.rescale(&f.ev.mul(&ca, &cb));
        assert_eq!(prod.level(), 2);
        let got = dec_vec(&f, &prod);
        for i in 0..half {
            assert!(
                (got[i] - a[i] * b[i]).abs() < 1e-3,
                "slot {i}: {} vs {}",
                got[i],
                a[i] * b[i]
            );
        }
        let c = f.ev.counters.snapshot();
        assert_eq!(c.cmult, 1);
        assert_eq!(c.rescale, 1);
    }

    #[test]
    fn test_full_depth_chain() {
        // consume every level with successive squarings: x^(2^L)
        let mut f = fixture(3, 9, &[]);
        let half = f.ctx.slots();
        let x = 0.9f64;
        let v = vec![x; half];
        let mut ct = enc_vec(&mut f, &v);
        let mut want = x;
        for _ in 0..3 {
            ct = f.ev.rescale(&f.ev.square(&ct));
            want = want * want;
        }
        assert_eq!(ct.level(), 0);
        let got = dec_vec(&f, &ct);
        assert!((got[0] - want).abs() < 2e-2, "{} vs {want}", got[0]);
    }

    #[test]
    fn test_pmult_and_rescale() {
        let mut f = fixture(2, 9, &[]);
        let half = f.ctx.slots();
        let a: Vec<f64> = (0..half).map(|i| (i % 9) as f64 / 9.0).collect();
        let w: Vec<f64> = (0..half).map(|i| ((i % 4) as f64 - 1.5) / 1.5).collect();
        let ca = enc_vec(&mut f, &a);
        let pw = f.enc.encode(&f.ctx, &w, f.ctx.scale, ca.nq());
        let r = f.ev.rescale(&f.ev.mul_plain(&ca, &pw));
        let got = dec_vec(&f, &r);
        for i in 0..half {
            assert!((got[i] - a[i] * w[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn test_rotation() {
        let mut f = fixture(2, 9, &[1, 3, 64]);
        let half = f.ctx.slots();
        let a: Vec<f64> = (0..half).map(|i| i as f64 / half as f64).collect();
        let ca = enc_vec(&mut f, &a);
        for k in [1usize, 3, 64] {
            let r = f.ev.rotate(&f.enc, &ca, k);
            let got = dec_vec(&f, &r);
            for i in 0..half {
                let want = a[(i + k) % half];
                assert!((got[i] - want).abs() < 1e-3, "k={k} i={i}");
            }
        }
        assert_eq!(f.ev.counters.snapshot().rot, 3);
    }

    #[test]
    fn test_rotation_by_zero_is_free() {
        let mut f = fixture(1, 8, &[]);
        let a = vec![0.5; f.ctx.slots()];
        let ca = enc_vec(&mut f, &a);
        let r = f.ev.rotate(&f.enc, &ca, 0);
        assert_eq!(f.ev.counters.snapshot().rot, 0);
        let got = dec_vec(&f, &r);
        assert!((got[0] - 0.5).abs() < 1e-4);
    }

    #[test]
    fn test_add_aligns_levels() {
        let mut f = fixture(2, 8, &[]);
        let half = f.ctx.slots();
        let a = vec![0.25; half];
        let ca = enc_vec(&mut f, &a);
        let cb = enc_vec(&mut f, &a);
        // drop cb one level, then add: result at the lower level
        let cb_low = f.ev.mod_drop_to_level(&cb, 1);
        let s = f.ev.add(&ca, &cb_low);
        assert_eq!(s.level(), 1);
        let got = dec_vec(&f, &s);
        assert!((got[0] - 0.5).abs() < 1e-3);
    }

    #[test]
    fn test_poly_activation_pattern() {
        // the paper's fused node-wise activation: y = (αx)² + w1·x + b
        // evaluated as CMult(x̃,x̃) + PMult(x, w1) + b — one level consumed.
        let mut f = fixture(2, 9, &[]);
        let half = f.ctx.slots();
        let xs: Vec<f64> = (0..half).map(|i| ((i % 11) as f64 - 5.0) / 5.0).collect();
        let (alpha, w1, b) = (0.6f64, 0.8f64, 0.1f64);
        let ct = enc_vec(&mut f, &xs);
        // x̃ = αx arrives pre-scaled from the previous fused conv: simulate
        let xt = f.ev.rescale(&f.ev.mul_scalar(&f.enc, &ct, alpha));
        let sq = f.ev.mul(&xt, &xt); // scale²
        let lin = f.ev.mul_scalar(&f.enc, &f.ev.mod_drop_to_level(&ct, xt.level()), w1);
        // align scales: sq at xt.scale², lin at ct.scale*Δ — rescale both
        let sq = f.ev.rescale(&sq);
        let lin = f.ev.rescale(&lin);
        let mut y = f.ev.add(&sq, &lin);
        let bias = f.enc.encode(&f.ctx, &vec![b; half], y.scale, y.nq());
        y = f.ev.add_plain(&y, &bias);
        let got = dec_vec(&f, &y);
        for i in 0..half {
            let want = (alpha * xs[i]).powi(2) + w1 * xs[i] + b;
            assert!((got[i] - want).abs() < 2e-2, "slot {i}: {} vs {want}", got[i]);
        }
    }

    #[test]
    fn test_rotate_group_bit_identical_to_single_rotations() {
        // the decompose-once Halevi–Shoup path must equal the per-step
        // path down to the last ciphertext bit (DESIGN.md S17)
        let mut f = fixture(3, 9, &[1, 3, 64, 100]);
        let half = f.ctx.slots();
        let a: Vec<f64> = (0..half).map(|i| ((i * 13 % 29) as f64 - 14.0) / 14.0).collect();
        let ca = enc_vec(&mut f, &a);
        let ks = [1usize, 3, 64, 100];
        let singles: Vec<Ciphertext> =
            ks.iter().map(|&k| f.ev.rotate(&f.enc, &ca, k)).collect();
        f.ev.counters.reset();
        let grouped = f.ev.rotate_group(&f.enc, &ca, &ks);
        assert_eq!(grouped.len(), ks.len());
        for (k, (g, s)) in ks.iter().zip(grouped.iter().zip(&singles)) {
            assert_eq!(g, s, "hoisted rotation by {k} changed ciphertext bits");
        }
        // ...and at a lower level (fewer limbs), after a rescale
        let low = f.ev.rescale(&f.ev.mul(&ca, &ca));
        let single_low = f.ev.rotate(&f.enc, &low, 3);
        let grouped_low = f.ev.rotate_group(&f.enc, &low, &[3]);
        assert_eq!(grouped_low[0], single_low);
    }

    #[test]
    fn test_rotate_group_counter_semantics() {
        let mut f = fixture(2, 8, &[1, 2, 5]);
        let a = vec![0.25; f.ctx.slots()];
        let ca = enc_vec(&mut f, &a);
        f.ev.counters.reset();
        let _ = f.ev.rotate_group(&f.enc, &ca, &[1, 2, 5]);
        let c = f.ev.counters.snapshot();
        assert_eq!(c.rot, 3, "each produced rotation tallies as a rot");
        assert_eq!(c.rot_group, 1);
        assert_eq!(c.ks_decomp, 1, "one shared decomposition for the group");
        let nq = ca.c0.nq as u64;
        assert_eq!(c.ks_decomp_limbs_sq, nq * nq);
        assert_eq!(c.rot_limbs, 3 * nq);
        // per-step path: one decomposition per rotation
        f.ev.counters.reset();
        for k in [1usize, 2, 5] {
            let _ = f.ev.rotate(&f.enc, &ca, k);
        }
        let c = f.ev.counters.snapshot();
        assert_eq!(c.ks_decomp, 3);
        assert_eq!(c.rot_group, 0);
    }

    #[test]
    fn test_fused_keyswitch_bit_identical_to_eager() {
        // the lazy-reduction inner product must reproduce the eager
        // Barrett-per-product path bit for bit across relinearization,
        // single rotations, and hoisted groups (flipping the toggle
        // mid-run is safe for concurrent tests precisely because the
        // paths are identical)
        let mut f = fixture(3, 9, &[1, 7]);
        let half = f.ctx.slots();
        let a: Vec<f64> = (0..half).map(|i| ((i * 7 % 23) as f64 - 11.0) / 11.0).collect();
        let ca = enc_vec(&mut f, &a);
        set_fused_keyswitch(true);
        let fused_mul = f.ev.mul(&ca, &ca);
        let fused_rot = f.ev.rotate(&f.enc, &ca, 7);
        let fused_grp = f.ev.rotate_group(&f.enc, &ca, &[1, 7]);
        set_fused_keyswitch(false);
        let eager_mul = f.ev.mul(&ca, &ca);
        let eager_rot = f.ev.rotate(&f.enc, &ca, 7);
        let eager_grp = f.ev.rotate_group(&f.enc, &ca, &[1, 7]);
        set_fused_keyswitch(true);
        assert_eq!(fused_mul, eager_mul, "relinearization diverged");
        assert_eq!(fused_rot, eager_rot, "rotation key switch diverged");
        assert_eq!(fused_grp, eager_grp, "hoisted group diverged");
    }

    #[test]
    fn test_counters_reset() {
        let mut f = fixture(1, 8, &[]);
        let a = vec![0.1; f.ctx.slots()];
        let ca = enc_vec(&mut f, &a);
        let _ = f.ev.add(&ca, &ca);
        assert!(f.ev.counters.snapshot().add > 0);
        f.ev.counters.reset();
        assert_eq!(f.ev.counters.snapshot(), OpCounts::default());
    }
}
