//! Linearization plans: which node-wise non-linear operators survive
//! (DESIGN.md S9).
//!
//! This is the rust-side representation of the output of the python
//! structural-linearization training (Algorithm 1); it also implements the
//! paper's two baselines for the ablations:
//! * **layer-wise** pruning (CryptoGCN-style): an activation layer is
//!   dropped for *all* nodes or none (Fig. 6b),
//! * **unstructured** pruning (SNL/DELPHI-style): arbitrary per-node bits,
//!   which the level planner shows saves *nothing* under CKKS (Fig. 3).

use crate::stgcn::{Activation, StgcnModel};
use anyhow::{ensure, Result};

/// Per-layer, per-position, per-node indicator bits (`h` in paper Eq. 2).
/// `true` = keep the non-linearity.
#[derive(Clone, Debug, PartialEq)]
pub struct LinearizationPlan {
    /// `plan[layer]` = (h1 over nodes, h2 over nodes).
    pub layers: Vec<(Vec<bool>, Vec<bool>)>,
}

impl LinearizationPlan {
    /// All activations kept (the un-pruned model).
    pub fn full(num_layers: usize, v: usize) -> Self {
        LinearizationPlan {
            layers: vec![(vec![true; v], vec![true; v]); num_layers],
        }
    }

    /// Per-node activation-count vector for one layer.
    fn counts(h1: &[bool], h2: &[bool]) -> Vec<usize> {
        h1.iter()
            .zip(h2)
            .map(|(&a, &b)| a as usize + b as usize)
            .collect()
    }

    /// Does the plan satisfy the structural constraint of Eq. 2
    /// (synchronized per-node counts within each layer)?
    pub fn is_structural(&self) -> bool {
        self.layers.iter().all(|(h1, h2)| {
            let c = Self::counts(h1, h2);
            c.iter().all(|&x| x == c[0])
        })
    }

    /// Effective non-linear layer count (paper's "Non-linear layers"
    /// column): Σ over layers of the synchronized per-node count.
    /// Errors when the plan is unstructured.
    pub fn effective_nonlinear_layers(&self) -> Result<usize> {
        ensure!(self.is_structural(), "plan violates structural constraint");
        Ok(self
            .layers
            .iter()
            .map(|(h1, h2)| Self::counts(h1, h2)[0])
            .sum())
    }

    /// Per-node total level consumption of the activation part — what the
    /// CKKS chain must budget. For a structural plan all entries are equal;
    /// for an unstructured one the *max* governs (Fig. 3's point).
    pub fn per_node_act_levels(&self) -> Vec<usize> {
        let v = self.layers[0].0.len();
        let mut totals = vec![0usize; v];
        for (h1, h2) in &self.layers {
            for (i, c) in Self::counts(h1, h2).iter().enumerate() {
                totals[i] += c;
            }
        }
        totals
    }

    /// Level budget the activations force: max over nodes (synchronized
    /// aggregation inputs must meet the deepest node).
    pub fn act_level_budget(&self) -> usize {
        self.per_node_act_levels().into_iter().max().unwrap_or(0)
    }

    /// Mean per-node non-linear count — the *compute* saved by a plan,
    /// distinct from the *level* budget. Unstructured plans reduce this
    /// without reducing `act_level_budget` — the paper's Observation 2.
    pub fn mean_act_count(&self) -> f64 {
        let t = self.per_node_act_levels();
        t.iter().sum::<usize>() as f64 / t.len() as f64
    }

    /// Layer-wise plan (CryptoGCN baseline): keep the first
    /// `kept_act_layers` activation positions (in network order), drop the
    /// rest for every node.
    pub fn layer_wise(num_layers: usize, v: usize, kept_act_layers: usize) -> Self {
        let mut plan = Vec::new();
        let mut budget = kept_act_layers;
        for _ in 0..num_layers {
            let h1 = vec![budget > 0; v];
            if budget > 0 {
                budget -= 1;
            }
            let h2 = vec![budget > 0; v];
            if budget > 0 {
                budget -= 1;
            }
            plan.push((h1, h2));
        }
        LinearizationPlan { layers: plan }
    }

    /// Structural plan with `kept` effective non-linear layers where nodes
    /// pick *different positions* (even nodes pos-1, odd nodes pos-2 when a
    /// layer keeps one) — exercising the paper's node-level freedom.
    pub fn structural_mixed(num_layers: usize, v: usize, kept: usize) -> Self {
        let mut plan = Vec::new();
        let mut budget = kept;
        for _ in 0..num_layers {
            let per_layer = budget.min(2);
            budget -= per_layer;
            let (h1, h2) = match per_layer {
                2 => (vec![true; v], vec![true; v]),
                1 => {
                    let h1: Vec<bool> = (0..v).map(|i| i % 2 == 0).collect();
                    let h2: Vec<bool> = (0..v).map(|i| i % 2 == 1).collect();
                    (h1, h2)
                }
                _ => (vec![false; v], vec![false; v]),
            };
            plan.push((h1, h2));
        }
        LinearizationPlan { layers: plan }
    }

    /// Unstructured plan: random per-node bits at a keep-probability —
    /// the strawman of Fig. 3(b).
    pub fn unstructured_random(
        num_layers: usize,
        v: usize,
        keep_prob: f64,
        rng: &mut crate::util::Rng,
    ) -> Self {
        let mk = |rng: &mut crate::util::Rng| -> Vec<bool> {
            (0..v).map(|_| rng.gen_f64() < keep_prob).collect()
        };
        LinearizationPlan {
            layers: (0..num_layers).map(|_| (mk(rng), mk(rng))).collect(),
        }
    }

    /// Apply to a model: pruned positions become `Identity`.
    pub fn apply(&self, model: &mut StgcnModel) -> Result<()> {
        ensure!(self.layers.len() == model.layers.len(), "layer count mismatch");
        for ((h1, h2), layer) in self.layers.iter().zip(model.layers.iter_mut()) {
            ensure!(h1.len() == layer.act1.len(), "node count mismatch");
            for (keep, act) in h1.iter().zip(layer.act1.iter_mut()) {
                if !keep {
                    *act = Activation::Identity;
                }
            }
            for (keep, act) in h2.iter().zip(layer.act2.iter_mut()) {
                if !keep {
                    *act = Activation::Identity;
                }
            }
        }
        Ok(())
    }

    /// Extract the plan already embedded in a model's activations.
    pub fn from_model(model: &StgcnModel) -> Self {
        LinearizationPlan {
            layers: model
                .layers
                .iter()
                .map(|l| {
                    (
                        l.act1.iter().map(|a| a.consumes_level()).collect(),
                        l.act2.iter().map(|a| a.consumes_level()).collect(),
                    )
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn test_full_plan() {
        let p = LinearizationPlan::full(3, 25);
        assert!(p.is_structural());
        assert_eq!(p.effective_nonlinear_layers().unwrap(), 6);
        assert_eq!(p.act_level_budget(), 6);
    }

    #[test]
    fn test_layer_wise_counts() {
        for kept in 0..=6 {
            let p = LinearizationPlan::layer_wise(3, 25, kept);
            assert!(p.is_structural());
            assert_eq!(p.effective_nonlinear_layers().unwrap(), kept, "kept={kept}");
        }
    }

    #[test]
    fn test_structural_mixed_counts_and_positions() {
        let p = LinearizationPlan::structural_mixed(3, 25, 3);
        assert!(p.is_structural());
        assert_eq!(p.effective_nonlinear_layers().unwrap(), 3);
        // second layer keeps 1 act/node at mixed positions
        let (h1, h2) = &p.layers[1];
        assert!(h1.iter().any(|&x| x) && !h1.iter().all(|&x| x));
        assert!(h2.iter().any(|&x| x) && !h2.iter().all(|&x| x));
    }

    #[test]
    fn test_unstructured_saves_no_levels() {
        // the Fig. 3 claim: unstructured pruning at 50% leaves the max
        // per-node depth at (or near) the full budget while halving compute
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let p = LinearizationPlan::unstructured_random(3, 25, 0.5, &mut rng);
        assert!(!p.is_structural());
        assert!(p.effective_nonlinear_layers().is_err());
        let full_budget = 6;
        assert!(
            p.act_level_budget() >= full_budget - 1,
            "unstructured budget {} unexpectedly low",
            p.act_level_budget()
        );
        assert!(p.mean_act_count() < 4.0, "compute did drop");
    }

    #[test]
    fn test_apply_and_extract_roundtrip() {
        let mut m = StgcnModel::synthetic(Graph::ring(6), 8, 2, 3, &[4, 4, 4], 3, 2);
        let p = LinearizationPlan::structural_mixed(3, 6, 2);
        p.apply(&mut m).unwrap();
        assert_eq!(m.effective_nonlinear_layers().unwrap(), 2);
        let back = LinearizationPlan::from_model(&m);
        assert_eq!(back, p);
    }
}
