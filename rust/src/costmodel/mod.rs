//! Calibrated HE-operation cost model (DESIGN.md S12, substitution #5).
//!
//! The paper's latency tables were measured with single-threaded SEAL on a
//! Threadripper; ours are *derived*, not asserted: we measure our own CKKS
//! op latencies on this machine across (N, limb-count) grid points, fit the
//! asymptotically-correct cost forms, and evaluate them on the exact op
//! counts the instrumented engine produces at the paper's HE parameters
//! (Table 6). Cost forms:
//!
//! * `Rot`, `CMult` (key-switching ops): `a · N·log2(N) · limbs² + b`
//!   (digit decomposition: `limbs` digits, each NTT'd over `limbs+1`
//!   moduli);
//! * `PMult`, `Add`: `a · N · limbs + b` (pointwise);
//! * `Rescale`: `a · N·log2(N) · limbs + b` (NTT round-trip per limb).
//!
//! Multi-ciphertext extrapolation: when the model's AMA block `C_max·T`
//! exceeds N/2, the paper splits each node across `ceil(block/(N/2))`
//! ciphertexts; op counts scale by the same factor (documented in
//! DESIGN.md).

pub mod predict;
pub mod report;

use crate::ckks::{CkksEngine, CkksParams, OpCounts};
use crate::util::bench::time_op;
use std::time::Duration;

/// One measured calibration point.
#[derive(Clone, Copy, Debug)]
pub struct CalibPoint {
    pub n: usize,
    pub limbs: usize,
    pub rot_s: f64,
    pub cmult_s: f64,
    pub pmult_s: f64,
    pub add_s: f64,
    pub rescale_s: f64,
}

/// Fitted per-op cost model.
#[derive(Clone, Copy, Debug)]
pub struct OpCostModel {
    /// seconds per (N·log2 N·limbs²)
    pub rot_a: f64,
    pub cmult_a: f64,
    /// seconds per (N·limbs)
    pub pmult_a: f64,
    pub add_a: f64,
    /// seconds per (N·log2 N·limbs)
    pub rescale_a: f64,
    /// Flat seconds per client-aided refresh round (DESIGN.md S21):
    /// loopback/LAN round trip plus the client's decrypt + re-encrypt.
    /// Not fitted from the HE-op grid — a round is network-bound, so a
    /// nominal constant is used and the serving metrics report measured
    /// round latency alongside it.
    pub refresh_s: f64,
}

/// Nominal per-round refresh latency (see [`OpCostModel::refresh_s`]).
pub const DEFAULT_REFRESH_ROUND_S: f64 = 0.05;

/// Latency prediction broken down the way the paper's Table 7 reports it.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyBreakdown {
    pub rot_s: f64,
    pub pmult_s: f64,
    pub add_s: f64,
    pub cmult_s: f64,
    pub rescale_s: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.rot_s + self.pmult_s + self.add_s + self.cmult_s + self.rescale_s
    }
}

impl OpCostModel {
    /// Fit from measured points by per-op least squares through the origin
    /// on the dominant feature.
    pub fn fit(points: &[CalibPoint]) -> Self {
        fn lsq(xy: impl Iterator<Item = (f64, f64)>) -> f64 {
            let (mut sxx, mut sxy) = (0.0, 0.0);
            for (x, y) in xy {
                sxx += x * x;
                sxy += x * y;
            }
            sxy / sxx
        }
        let nlog = |p: &CalibPoint| p.n as f64 * (p.n as f64).log2();
        OpCostModel {
            rot_a: lsq(points
                .iter()
                .map(|p| (nlog(p) * (p.limbs * p.limbs) as f64, p.rot_s))),
            cmult_a: lsq(points
                .iter()
                .map(|p| (nlog(p) * (p.limbs * p.limbs) as f64, p.cmult_s))),
            pmult_a: lsq(points
                .iter()
                .map(|p| ((p.n * p.limbs) as f64, p.pmult_s))),
            add_a: lsq(points.iter().map(|p| ((p.n * p.limbs) as f64, p.add_s))),
            rescale_a: lsq(points
                .iter()
                .map(|p| (nlog(p) * p.limbs as f64, p.rescale_s))),
            refresh_s: DEFAULT_REFRESH_ROUND_S,
        }
    }

    /// Measure real op latencies across a small (N, levels) grid and fit.
    /// Takes tens of seconds; benches cache the result.
    pub fn calibrate() -> anyhow::Result<Self> {
        let mut points = Vec::new();
        for (log_n, levels) in [(11u32, 4usize), (12, 6), (13, 8)] {
            points.push(measure_point(1 << log_n, levels)?);
        }
        Ok(Self::fit(&points))
    }

    /// Single-point calibration at N=2^11 with a tight time budget —
    /// seconds instead of tens of seconds, at the cost of extrapolating
    /// the N-dependence entirely from the fitted cost forms. Used by the
    /// CLI's `calibrate --quick` and the CLI smoke tests.
    pub fn calibrate_quick() -> anyhow::Result<Self> {
        let p = measure_point_budget(1 << 11, 2, Duration::from_millis(60))?;
        Ok(Self::fit(&[p]))
    }

    /// A reference model fitted on this machine after the §Perf pass
    /// (Barrett + NTT-domain automorphism + plaintext cache); regenerate
    /// with `cargo bench --bench he_ops -- --recalibrate`.
    pub fn reference() -> Self {
        // seconds per feature unit (see module docs for the feature forms)
        OpCostModel {
            rot_a: 4.6e-9,
            cmult_a: 5.0e-9,
            pmult_a: 8.5e-9,
            add_a: 6.9e-9,
            rescale_a: 7.5e-9,
            refresh_s: DEFAULT_REFRESH_ROUND_S,
        }
    }

    fn rot_cost(&self, n: usize, limbs_sq: f64) -> f64 {
        n as f64 * (n as f64).log2() * limbs_sq * self.rot_a
    }

    /// Predict the latency breakdown for an op-count profile at ring
    /// degree `n`, multiplied by the ciphertext `split` factor.
    pub fn estimate(&self, n: usize, counts: &OpCounts, split: usize) -> LatencyBreakdown {
        let s = split as f64;
        let nlog = n as f64 * (n as f64).log2();
        LatencyBreakdown {
            rot_s: s * self.rot_cost(n, counts.rot_limbs_sq as f64),
            cmult_s: s * nlog * counts.cmult_limbs_sq as f64 * self.cmult_a,
            pmult_s: s * (n as f64) * counts.pmult_limbs as f64 * self.pmult_a,
            add_s: s * (n as f64) * counts.add_limbs as f64 * self.add_a,
            rescale_s: s * nlog * counts.rescale_limbs as f64 * self.rescale_a,
        }
    }
}

/// Measure one calibration point on a real engine (default 400 ms budget
/// per op).
pub fn measure_point(n: usize, levels: usize) -> anyhow::Result<CalibPoint> {
    measure_point_budget(n, levels, Duration::from_millis(400))
}

/// Measure one calibration point with an explicit per-op time budget.
pub fn measure_point_budget(
    n: usize,
    levels: usize,
    budget: Duration,
) -> anyhow::Result<CalibPoint> {
    let params = CkksParams {
        n,
        q0_bits: 50,
        scale_bits: 33,
        levels,
        special_bits: 55,
        allow_insecure: true,
    };
    let engine = CkksEngine::new(params, &[1], 7)?;
    let half = engine.ctx.slots();
    let vals: Vec<f64> = (0..half).map(|i| ((i % 97) as f64 - 48.0) / 64.0).collect();
    let a = engine.encrypt(&vals);
    let b = engine.encrypt(&vals);
    let pt = engine.encode_for(&vals, &a);
    let limbs = levels + 1;

    let rot = time_op(1, 8, budget, || {
        let _ = engine.eval.rotate(&engine.encoder, &a, 1);
    });
    let cmult = time_op(1, 8, budget, || {
        let _ = engine.eval.mul(&a, &b);
    });
    let pmult = time_op(1, 8, budget, || {
        let _ = engine.eval.mul_plain(&a, &pt);
    });
    let add = time_op(1, 8, budget, || {
        let _ = engine.eval.add(&a, &b);
    });
    let prod = engine.eval.mul(&a, &b);
    let rescale = time_op(1, 8, budget, || {
        let _ = engine.eval.rescale(&prod);
    });

    Ok(CalibPoint {
        n,
        limbs,
        rot_s: rot.median_secs(),
        cmult_s: cmult.median_secs(),
        pmult_s: pmult.median_secs(),
        add_s: add.median_secs(),
        rescale_s: rescale.median_secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_points() -> Vec<CalibPoint> {
        // synthetic data following the model forms exactly
        let mk = |n: usize, limbs: usize| {
            let nlog = n as f64 * (n as f64).log2();
            CalibPoint {
                n,
                limbs,
                rot_s: 2e-9 * nlog * (limbs * limbs) as f64,
                cmult_s: 3e-9 * nlog * (limbs * limbs) as f64,
                pmult_s: 2e-9 * (n * limbs) as f64,
                add_s: 5e-10 * (n * limbs) as f64,
                rescale_s: 2e-8 * nlog * limbs as f64,
            }
        };
        vec![mk(2048, 5), mk(4096, 7), mk(8192, 9)]
    }

    #[test]
    fn test_fit_recovers_coefficients() {
        let m = OpCostModel::fit(&fake_points());
        assert!((m.rot_a - 2e-9).abs() / 2e-9 < 1e-9);
        assert!((m.pmult_a - 2e-9).abs() / 2e-9 < 1e-9);
        assert!((m.rescale_a - 2e-8).abs() / 2e-8 < 1e-9);
    }

    #[test]
    fn test_estimate_monotone_in_n_and_split() {
        let m = OpCostModel::reference();
        let counts = OpCounts {
            rot: 100,
            rot_limbs: 1000,
            rot_limbs_sq: 12000,
            pmult: 500,
            pmult_limbs: 5000,
            add: 500,
            add_limbs: 5000,
            cmult: 50,
            cmult_limbs: 500,
            cmult_limbs_sq: 6000,
            rescale: 100,
            rescale_limbs: 900,
            ..Default::default()
        };
        let small = m.estimate(1 << 14, &counts, 1);
        let big = m.estimate(1 << 15, &counts, 1);
        assert!(big.total() > small.total());
        let split = m.estimate(1 << 14, &counts, 2);
        assert!((split.total() - 2.0 * small.total()).abs() < 1e-12);
    }

    #[test]
    fn test_rot_dominates_breakdown_for_rot_heavy_profile() {
        // Table 7 shape: Rot is the dominant cost component
        let m = OpCostModel::reference();
        let counts = OpCounts {
            rot: 10_000,
            rot_limbs: 120_000,
            rot_limbs_sq: 1_500_000,
            pmult: 30_000,
            pmult_limbs: 300_000,
            add: 30_000,
            add_limbs: 300_000,
            cmult: 300,
            cmult_limbs: 3_000,
            cmult_limbs_sq: 30_000,
            rescale: 2_000,
            rescale_limbs: 20_000,
            ..Default::default()
        };
        let b = m.estimate(1 << 15, &counts, 1);
        assert!(b.rot_s > b.pmult_s && b.rot_s > b.add_s && b.rot_s > b.cmult_s);
    }

    #[test]
    #[ignore = "slow: real measurement (~seconds); run with --ignored"]
    fn test_real_calibration_sane() {
        let p = measure_point(1 << 11, 4).unwrap();
        assert!(p.rot_s > p.add_s, "rotation must cost more than add");
        assert!(p.cmult_s > p.pmult_s, "cmult must cost more than pmult");
    }
}
