//! Paper-scale latency prediction: build the paper's model variants at
//! their true dimensions, run the instrumented engine on the counting
//! backend, select HE parameters via the Table 6 planner, and price the
//! op profile with the calibrated cost model.
//!
//! The ciphertext-split rule matches the paper's Appendix A.1 exactly:
//! at N=2^16 a 256×256 feature map fills one ciphertext per node (25
//! total); N=2^15 → 2 per node (50); N=2^14 → 4 per node (100).

use super::{LatencyBreakdown, OpCostModel};
use crate::ama::AmaLayout;
use crate::ckks::OpCounts;
use crate::graph::Graph;
use crate::he_infer::level_plan::{HePlanParams, Method, VariantShape};
use crate::he_infer::{CountingBackend, HeBackend, HeStgcn};
use crate::linearize::LinearizationPlan;
use crate::stgcn::StgcnModel;
use anyhow::Result;

/// One of the paper's evaluated model families at true dimensions.
#[derive(Clone, Debug)]
pub struct PaperVariant {
    pub name: String,
    /// Per-layer output channels, e.g. [64, 128, 128] for STGCN-3-128.
    pub channels: Vec<usize>,
    pub c_in: usize,
    pub t: usize,
    pub classes: usize,
    pub k: usize,
    /// Effective non-linear layers kept.
    pub nl: usize,
    pub method: Method,
}

impl PaperVariant {
    pub fn stgcn_3_128(nl: usize, method: Method) -> Self {
        PaperVariant {
            name: format!("{nl}-STGCN-3-128"),
            channels: vec![64, 128, 128],
            c_in: 4, // paper uses 3; padded to 4 for block alignment
            t: 256,
            classes: 60,
            k: 9,
            nl,
            method,
        }
    }

    pub fn stgcn_3_256(nl: usize, method: Method) -> Self {
        PaperVariant {
            name: format!("{nl}-STGCN-3-256"),
            channels: vec![128, 256, 256],
            c_in: 4,
            t: 256,
            classes: 60,
            k: 9,
            nl,
            method,
        }
    }

    pub fn stgcn_6_256(nl: usize, method: Method) -> Self {
        PaperVariant {
            name: format!("{nl}-STGCN-6-256"),
            channels: vec![64, 64, 128, 128, 256, 256],
            c_in: 4,
            t: 256,
            classes: 60,
            k: 9,
            nl,
            method,
        }
    }

    pub fn c_max(&self) -> usize {
        *self.channels.iter().max().unwrap()
    }

    pub fn shape(&self) -> VariantShape {
        VariantShape {
            layers: self.channels.len(),
            nonlinear_layers: self.nl,
            method: self.method,
        }
    }
}

/// A predicted table row.
#[derive(Clone, Debug)]
pub struct PredictedRow {
    pub name: String,
    pub nl: usize,
    pub he: HePlanParams,
    /// Ciphertexts per node (Appendix A.1 split rule).
    pub split: usize,
    pub counts: OpCounts,
    pub breakdown: LatencyBreakdown,
    pub total_s: f64,
}

/// Run the instrumented engine for `variant` and price it.
pub fn predict(variant: &PaperVariant, cost: &OpCostModel) -> Result<PredictedRow> {
    let he_params = variant.shape().plan()?;
    let graph = Graph::ntu_rgbd();
    let v = graph.v;
    let mut model = StgcnModel::synthetic(
        graph,
        variant.t,
        variant.c_in,
        variant.k,
        &variant.channels,
        variant.classes,
        42,
    );
    let plan = match variant.method {
        Method::LinGcn => LinearizationPlan::structural_mixed(variant.channels.len(), v, variant.nl),
        Method::CryptoGcn => LinearizationPlan::layer_wise(variant.channels.len(), v, variant.nl),
    };
    plan.apply(&mut model)?;

    // virtual single-ciphertext layout at the full block size; the split
    // factor converts to the real multi-ciphertext execution
    let block = variant.c_max() * variant.t;
    let layout = AmaLayout::new(variant.t, variant.c_max(), block)?;
    let mut he = HeStgcn::new(&model, layout)?;
    he.fuse_activations = matches!(variant.method, Method::LinGcn);

    let be = CountingBackend::new(he_params.levels, he_params.scale_bits);
    let input: Vec<_> = (0..v).map(|_| be.fresh()).collect();
    let out = he.forward(&be, &input)?;
    // 6-layer plans budget one extra level for the strided-residual path
    // (paper Table 6); the synthetic counting model has no stride, so it
    // may finish one level above zero.
    anyhow::ensure!(be.level(&out) <= 1, "depth budget mismatch in prediction");

    let counts = be.op_counts();
    let slots = he_params.n / 2;
    let split = block.div_ceil(slots);
    let breakdown = cost.estimate(he_params.n, &counts, split);
    Ok(PredictedRow {
        name: variant.name.clone(),
        nl: variant.nl,
        he: he_params,
        split,
        counts,
        breakdown,
        total_s: breakdown.total(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_split_rule_matches_appendix_a1() {
        let cost = OpCostModel::reference();
        // 6-NL 3-256 → N=2^15 → block 65536 / 16384 = 4? paper says 50 cts
        // at N=2^15 for the 256-wide model... their count is per the
        // *128-wide* model; check both families:
        let r128 = predict(&PaperVariant::stgcn_3_128(6, Method::LinGcn), &cost).unwrap();
        assert_eq!(r128.he.n, 32768);
        // block = 128·256 = 32768, slots = 16384 → split 2 → 50 ciphertexts
        assert_eq!(r128.split, 2);
        let r128_low = predict(&PaperVariant::stgcn_3_128(2, Method::LinGcn), &cost).unwrap();
        assert_eq!(r128_low.he.n, 16384);
        assert_eq!(r128_low.split, 4); // 100 ciphertexts
        let r256 = predict(&PaperVariant::stgcn_6_256(12, Method::LinGcn), &cost).unwrap();
        assert_eq!(r256.he.n, 65536);
        assert_eq!(r256.split, 2);
    }

    #[test]
    fn test_latency_decreases_with_linearization() {
        let cost = OpCostModel::reference();
        let mut prev = f64::INFINITY;
        for nl in [6usize, 4, 2, 1] {
            let r = predict(&PaperVariant::stgcn_3_128(nl, Method::LinGcn), &cost).unwrap();
            assert!(
                r.total_s < prev,
                "nl={nl}: {} !< {prev}",
                r.total_s
            );
            prev = r.total_s;
        }
    }

    #[test]
    fn test_lingcn_beats_cryptogcn_at_same_nl() {
        let cost = OpCostModel::reference();
        for nl in [6usize, 4] {
            let lin = predict(&PaperVariant::stgcn_3_128(nl, Method::LinGcn), &cost).unwrap();
            let cg = predict(&PaperVariant::stgcn_3_128(nl, Method::CryptoGcn), &cost).unwrap();
            assert!(
                cg.total_s > lin.total_s,
                "nl={nl}: CryptoGCN {} must exceed LinGCN {}",
                cg.total_s,
                lin.total_s
            );
        }
    }

    #[test]
    fn test_rot_dominates_at_paper_scale() {
        // Table 7's key observation
        let cost = OpCostModel::reference();
        let r = predict(&PaperVariant::stgcn_3_128(6, Method::LinGcn), &cost).unwrap();
        assert!(r.breakdown.rot_s > r.breakdown.pmult_s);
        assert!(r.breakdown.rot_s > r.breakdown.cmult_s);
        assert!(r.breakdown.rot_s > r.breakdown.add_s);
    }
}
