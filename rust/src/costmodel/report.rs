//! Table/figure generators: each function reproduces one of the paper's
//! evaluation artifacts (rows in the same format), pairing our predicted
//! numbers with the paper's reported values so the *shape* comparison
//! (who wins, by what factor, where the crossovers fall) is immediate.
//! The bench targets (`rust/benches/*`) are thin wrappers over these.

use super::predict::{predict, PaperVariant, PredictedRow};
use super::OpCostModel;
use crate::he_infer::Method;
use anyhow::Result;

/// Paper Table 2 (STGCN-3-128): (method, nl, paper_acc, paper_latency_s).
pub const PAPER_TABLE2: &[(&str, usize, f64, f64)] = &[
    ("LinGCN", 6, 77.55, 1856.95),
    ("LinGCN", 5, 75.48, 1663.13),
    ("LinGCN", 4, 76.33, 1458.95),
    ("LinGCN", 3, 74.27, 850.22),
    ("LinGCN", 2, 75.16, 741.55),
    ("LinGCN", 1, 69.61, 642.06),
    ("CryptoGCN", 6, 74.25, 4273.89),
    ("CryptoGCN", 5, 73.12, 1863.95),
    ("CryptoGCN", 4, 70.21, 1856.36),
];

/// Paper Table 3 (STGCN-3-256).
pub const PAPER_TABLE3: &[(&str, usize, f64, f64)] = &[
    ("LinGCN", 6, 80.29, 4632.05),
    ("LinGCN", 5, 79.07, 4166.12),
    ("LinGCN", 4, 78.59, 3699.49),
    ("LinGCN", 3, 76.41, 2428.88),
    ("LinGCN", 2, 74.74, 2143.46),
    ("LinGCN", 1, 71.98, 1873.40),
    ("CryptoGCN", 6, 75.31, 10580.41),
    ("CryptoGCN", 5, 73.78, 4850.93),
    ("CryptoGCN", 4, 71.36, 4831.93),
];

/// Paper Table 4 (STGCN-6-256), LinGCN only.
pub const PAPER_TABLE4: &[(&str, usize, f64, f64)] = &[
    ("LinGCN", 12, 85.47, 21171.80),
    ("LinGCN", 11, 86.24, 19553.96),
    ("LinGCN", 7, 85.08, 8186.35),
    ("LinGCN", 5, 83.64, 7063.51),
    ("LinGCN", 4, 85.78, 6371.39),
    ("LinGCN", 3, 84.28, 5944.81),
    ("LinGCN", 2, 82.27, 5456.12),
    ("LinGCN", 1, 75.93, 4927.26),
];

/// Paper Table 7 rows: (model, rot_s, pmult_s, add_s, cmult_s, total_s).
pub const PAPER_TABLE7: &[(&str, f64, f64, f64, f64, f64)] = &[
    ("6-STGCN-3-128", 1336.25, 378.25, 99.65, 37.45, 1851.60),
    ("2-STGCN-3-128", 392.21, 266.13, 68.90, 14.31, 741.55),
    ("6-STGCN-3-256", 2641.09, 1508.19, 397.17, 74.90, 4621.36),
    ("2-STGCN-3-256", 777.68, 1062.21, 274.96, 28.63, 2143.47),
    ("12-STGCN-6-256", 18955.09, 1545.09, 396.23, 275.39, 21171.80),
    ("2-STGCN-6-256", 4090.08, 1006.79, 244.19, 115.05, 5456.12),
];

/// One comparison row: ours vs paper.
#[derive(Clone, Debug)]
pub struct ComparisonRow {
    pub method: &'static str,
    pub nl: usize,
    pub ours: PredictedRow,
    pub paper_latency_s: f64,
    pub paper_acc: f64,
}

fn family_fn(table: u8) -> fn(usize, Method) -> PaperVariant {
    match table {
        2 => PaperVariant::stgcn_3_128,
        3 => PaperVariant::stgcn_3_256,
        4 => PaperVariant::stgcn_6_256,
        _ => unreachable!(),
    }
}

/// Generate our predicted rows for paper table `which` (2, 3 or 4).
pub fn table_rows(which: u8, cost: &OpCostModel) -> Result<Vec<ComparisonRow>> {
    let paper = match which {
        2 => PAPER_TABLE2,
        3 => PAPER_TABLE3,
        4 => PAPER_TABLE4,
        _ => anyhow::bail!("unknown table {which}"),
    };
    let mk = family_fn(which);
    paper
        .iter()
        .map(|&(method, nl, paper_acc, paper_latency_s)| {
            let m = if method == "LinGCN" {
                Method::LinGcn
            } else {
                Method::CryptoGcn
            };
            Ok(ComparisonRow {
                method,
                nl,
                ours: predict(&mk(nl, m), cost)?,
                paper_latency_s,
                paper_acc,
            })
        })
        .collect()
}

/// Format a table comparison for printing.
pub fn render_table(rows: &[ComparisonRow], title: &str) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.to_string(),
                r.nl.to_string(),
                r.ours.he.n.to_string(),
                r.ours.he.levels.to_string(),
                format!("{:.0}", r.ours.total_s),
                format!("{:.0}", r.paper_latency_s),
                format!("{:.2}", r.ours.total_s / r.paper_latency_s),
                format!("{:.2}", r.paper_acc),
            ]
        })
        .collect();
    format!(
        "{title}\n{}",
        crate::util::ascii_table(
            &[
                "Method",
                "NL",
                "N",
                "L",
                "ours (s)",
                "paper (s)",
                "ratio",
                "paper acc %"
            ],
            &body,
        )
    )
}

/// The headline Fig. 1 numbers: iso-accuracy speedup of LinGCN over
/// CryptoGCN. The paper reports 14.2× at ~75% accuracy (LinGCN 2-NL vs
/// CryptoGCN 6-NL on STGCN-3-256: 10580.41 / 741.55). We recompute the
/// same pairing from our predictions: LinGCN 2-NL STGCN-3-128 vs
/// CryptoGCN 6-NL STGCN-3-256.
pub fn iso_accuracy_speedup(cost: &OpCostModel) -> Result<(f64, f64)> {
    let lin = predict(&PaperVariant::stgcn_3_128(2, Method::LinGcn), cost)?;
    let cg = predict(&PaperVariant::stgcn_3_256(6, Method::CryptoGcn), cost)?;
    let ours = cg.total_s / lin.total_s;
    let paper = 10580.41 / 741.55;
    Ok((ours, paper))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_table2_shape_holds() {
        let cost = OpCostModel::reference();
        let rows = table_rows(2, &cost).unwrap();
        // LinGCN latency monotone decreasing with nl
        let lin: Vec<f64> = rows
            .iter()
            .filter(|r| r.method == "LinGCN")
            .map(|r| r.ours.total_s)
            .collect();
        assert!(lin.windows(2).all(|w| w[0] > w[1]), "{lin:?}");
        // CryptoGCN 6-NL slower than LinGCN 6-NL by >1.5× (paper: 2.3×)
        let l6 = rows.iter().find(|r| r.method == "LinGCN" && r.nl == 6).unwrap();
        let c6 = rows
            .iter()
            .find(|r| r.method == "CryptoGCN" && r.nl == 6)
            .unwrap();
        let factor = c6.ours.total_s / l6.ours.total_s;
        assert!(factor > 1.5, "CryptoGCN/LinGCN factor {factor}");
        // the N cliff between 4 and 3 NL produces a >20% latency drop
        let l4 = rows.iter().find(|r| r.method == "LinGCN" && r.nl == 4).unwrap();
        let l3 = rows.iter().find(|r| r.method == "LinGCN" && r.nl == 3).unwrap();
        assert!(l3.ours.total_s < 0.9 * l4.ours.total_s, "cliff: {} vs {}", l3.ours.total_s, l4.ours.total_s);
    }

    #[test]
    fn test_iso_accuracy_speedup_order_of_magnitude() {
        let cost = OpCostModel::reference();
        let (ours, paper) = iso_accuracy_speedup(&cost).unwrap();
        assert!(paper > 14.0 && paper < 14.5);
        assert!(
            ours > 5.0 && ours < 45.0,
            "iso-accuracy speedup {ours} out of plausible band vs paper {paper}"
        );
    }

    #[test]
    fn test_render_table_runs() {
        let cost = OpCostModel::reference();
        let rows = table_rows(4, &cost).unwrap();
        let s = render_table(&rows, "Table 4");
        assert!(s.contains("LinGCN"));
        assert!(s.lines().count() > 8);
    }
}
