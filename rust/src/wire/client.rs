//! The **client half** of the privacy boundary: key generation from a
//! seed, clip encryption, and logits decryption. A `ClientKeys` value is
//! the only serializable holder of secret material in the codebase, and
//! its file (`KIND_CLIENT_KEYS`) is a *local* persistence format — it
//! never crosses the wire. What ships to the server is the [`EvalKeySet`]
//! returned alongside it.
//!
//! Key generation mirrors `CkksEngine::new`'s draw order exactly (secret,
//! public, relin, Galois — one seeded stream), so for the same seed and
//! rotation set the split-process wire path produces bit-identical keys,
//! ciphertexts and logits to the in-process
//! `he_infer::PrivateInferenceSession` (asserted by
//! `rust/tests/wire_roundtrip.rs`).

use super::codec::{ByteReader, ByteWriter, KIND_CLIENT_KEYS};
use super::format::{read_poly, write_poly, CtBundle, EvalKeySet, WireSerialize};
use crate::ama::AmaLayout;
use crate::ckks::keys::{keygen_public, keygen_secret};
use crate::ckks::{
    build_eval_keys, encrypt, Ciphertext, CkksContext, CkksParams, Encoder, PublicKey, SecretKey,
};
use crate::he_infer::{
    compile, decide, session_geometry, Decision, OutputMode, PlanChain, PlanOptions,
};
use crate::stgcn::StgcnModel;
use crate::util::Rng;
use anyhow::{ensure, Context, Result};
use std::sync::{Arc, Mutex};

/// Everything the client must know about a variant to encrypt requests
/// and read logits **without holding the model**: the published half of
/// the server's serving geometry (`he_infer::exec::session_geometry`)
/// plus the logits extraction shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VariantSpec {
    /// Graph nodes (one ciphertext each).
    pub v: usize,
    /// Input channels of a clip.
    pub c_in: usize,
    /// Frames per clip.
    pub t: usize,
    /// AMA channel capacity.
    pub c_max: usize,
    /// Ciphertext slot count (N/2).
    pub slots: usize,
    /// Multiplicative depth of the chain (inputs encrypt at `levels + 1`
    /// limbs — the plan top).
    pub levels: usize,
    /// Output classes (logit `m` lives in slot `m·t`).
    pub num_classes: usize,
}

impl VariantSpec {
    pub fn for_model(model: &StgcnModel, layout: &AmaLayout, params: &CkksParams) -> Self {
        VariantSpec {
            v: model.v(),
            c_in: model.c_in,
            t: layout.t,
            c_max: layout.c_max,
            slots: layout.slots,
            levels: params.levels,
            num_classes: model.num_classes(),
        }
    }

    pub fn layout(&self) -> Result<AmaLayout> {
        AmaLayout::new(self.t, self.c_max, self.slots)
    }

    /// Block copies of the variant's layout — the maximum slot-batch size
    /// a request bundle for this variant can carry (DESIGN.md S16).
    pub fn copies(&self) -> usize {
        AmaLayout { t: self.t, c_max: self.c_max, slots: self.slots }.copies()
    }

    /// Limbs a fresh encryption carries — the full chain, `levels + 1`
    /// (the client-side published value of `HePlan::input_limbs`). Both
    /// request inputs and refreshed intermediates (DESIGN.md S21)
    /// re-enter the chain here.
    pub fn input_limbs(&self) -> usize {
        self.levels + 1
    }
}

/// Client-side key material and crypto operations. Holds the secret key;
/// lives on the client, never on the serving side.
pub struct ClientKeys {
    pub variant: String,
    pub spec: VariantSpec,
    pub params: CkksParams,
    ctx: Arc<CkksContext>,
    encoder: Encoder,
    sk: SecretKey,
    pk: PublicKey,
    rng: Mutex<Rng>,
}

impl ClientKeys {
    /// Generate a fresh key pair plus the server-shippable [`EvalKeySet`]
    /// covering `rotations` (the variant plan's `required_rotations`).
    /// A u64 seed caps the keyspace at 2^64 — fine for the reproducible
    /// test paths this signature serves; real deployments seed full
    /// 256-bit state via [`keygen_with_state`].
    pub fn generate(
        variant: &str,
        spec: VariantSpec,
        params: CkksParams,
        rotations: &[usize],
        seed: u64,
    ) -> Result<(ClientKeys, EvalKeySet)> {
        let ctx = params.build()?;
        Self::generate_with_ctx(variant, spec, params, ctx, rotations, Rng::seed_from_u64(seed))
    }

    /// [`ClientKeys::generate`] against an already-built context (callers
    /// like [`keygen`] have one from compiling the plan — context
    /// construction is the expensive part at paper-scale N) and a
    /// caller-seeded generator.
    pub fn generate_with_ctx(
        variant: &str,
        spec: VariantSpec,
        params: CkksParams,
        ctx: Arc<CkksContext>,
        rotations: &[usize],
        mut rng: Rng,
    ) -> Result<(ClientKeys, EvalKeySet)> {
        ensure!(
            ctx.slots() == spec.slots && ctx.max_level() == spec.levels,
            "variant spec geometry disagrees with the parameter set"
        );
        let encoder = Encoder::new(ctx.n);
        // one stream, same draw order as CkksEngine::new
        let sk = keygen_secret(&ctx, &mut rng);
        let pk = keygen_public(&ctx, &sk, &mut rng);
        let keys = build_eval_keys(&ctx, &encoder, &sk, rotations, false, &mut rng);
        let key_set = EvalKeySet {
            variant: variant.to_string(),
            params: params.clone(),
            keys: Arc::new(keys),
        };
        Ok((
            ClientKeys {
                variant: variant.to_string(),
                spec,
                params,
                ctx,
                encoder,
                sk,
                pk,
                rng: Mutex::new(rng),
            },
            key_set,
        ))
    }

    /// Encrypt a `[V, C_in, T]` clip into per-node ciphertexts at the
    /// plan's top level — the same `ama::pack_clip` packing and
    /// encode-then-encrypt steps as the in-process session, so the wire
    /// path's ciphertexts are bit-identical to `encrypt_clip`'s.
    ///
    /// Advances the encryption RNG. A caller that persists this value as
    /// a key file **must re-serialize it after encrypting** (the CLI
    /// does): re-running from a stale file would reuse the same
    /// encryption randomness, which leaks plaintext differences.
    pub fn encrypt_clip(&self, x: &[f64]) -> Result<Vec<Ciphertext>> {
        let layout = self.spec.layout()?;
        let packed = crate::ama::pack_clip(&layout, x, self.spec.v, self.spec.c_in)?;
        self.encrypt_packed(packed)
    }

    /// Slot-pack up to `copies()` distinct clips into one per-node
    /// ciphertext set (clip `b` in block copy `b`; DESIGN.md S16). A
    /// batch of one keeps the replicated layout the single-clip plan's
    /// rotation closure relies on — bit-identical to
    /// [`ClientKeys::encrypt_clip`]. Requests built this way need keys
    /// generated with a batched `PlanOptions` (`keygen --batch`), since
    /// block-closed plans rotate through extra wrap steps.
    pub fn encrypt_clip_batch(&self, clips: &[&[f64]]) -> Result<Vec<Ciphertext>> {
        ensure!(!clips.is_empty(), "need at least one clip");
        let layout = self.spec.layout()?;
        ensure!(
            clips.len() <= layout.copies(),
            "batch {} exceeds variant {}'s {} block copies",
            clips.len(),
            self.variant,
            layout.copies()
        );
        let packed = if clips.len() == 1 {
            crate::ama::pack_clip(&layout, clips[0], self.spec.v, self.spec.c_in)?
        } else {
            crate::ama::pack_clip_batch(&layout, clips, self.spec.v, self.spec.c_in)?
        };
        self.encrypt_packed(packed)
    }

    /// Shared encode-then-encrypt step of the single and batched paths.
    fn encrypt_packed(&self, packed: Vec<Vec<f64>>) -> Result<Vec<Ciphertext>> {
        let nq = self.spec.input_limbs();
        let mut rng = self.rng.lock().unwrap();
        Ok(packed
            .into_iter()
            .map(|slots| {
                let pt = self.encoder.encode(&self.ctx, &slots, self.ctx.scale, nq);
                encrypt::encrypt(&self.ctx, &self.pk, &pt, &mut *rng)
            })
            .collect())
    }

    /// Encrypt a clip and stamp it into a shippable [`CtBundle`].
    pub fn encrypt_request(&self, x: &[f64]) -> Result<CtBundle> {
        Ok(CtBundle::new(&self.params, self.encrypt_clip(x)?))
    }

    /// Encrypt a slot-packed batch of clips into a shippable [`CtBundle`]
    /// carrying its batch size.
    pub fn encrypt_request_batch(&self, clips: &[&[f64]]) -> Result<CtBundle> {
        Ok(CtBundle::new_batched(
            &self.params,
            self.encrypt_clip_batch(clips)?,
            clips.len(),
        ))
    }

    /// Mix fresh entropy into the encryption RNG. The CLI calls this per
    /// invocation so concurrent `encrypt` runs — or a restored backup of
    /// the key file — can never replay the same randomness stream
    /// against different plaintexts. XORing uniform entropy into the
    /// state yields a uniform state; the all-zero state (invalid for
    /// xoshiro) is patched.
    pub fn mix_entropy(&self, entropy: [u64; 4]) {
        let mut rng = self.rng.lock().unwrap();
        let s = rng.state();
        let mut mixed = [
            s[0] ^ entropy[0],
            s[1] ^ entropy[1],
            s[2] ^ entropy[2],
            s[3] ^ entropy[3],
        ];
        if mixed == [0u64; 4] {
            mixed[0] = 1;
        }
        *rng = Rng::from_state(mixed);
    }

    /// Decrypt a logits ciphertext returned by the server and extract the
    /// class scores (slot `m·t` per class, mirroring
    /// `HePlan::extract_logits`). One code path with the batched variant
    /// — the validation hardening can never drift between the two.
    pub fn decrypt_logits(&self, ct: &Ciphertext) -> Result<Vec<f64>> {
        Ok(self.decrypt_logits_batch(ct, 1)?.remove(0))
    }

    /// Decrypt the per-clip logits of a slot-batched response: clip `b`'s
    /// class scores live at `b·block + m·T`. `batch` must match what the
    /// request bundle carried; the geometry is validated so a corrupt
    /// response (or a wrong batch) errors instead of indexing garbage.
    pub fn decrypt_logits_batch(
        &self,
        ct: &Ciphertext,
        batch: usize,
    ) -> Result<Vec<Vec<f64>>> {
        let layout = self.spec.layout()?;
        ensure!(
            batch >= 1 && batch <= layout.copies(),
            "batch {batch} outside 1..={} (variant {}'s copies)",
            layout.copies(),
            self.variant
        );
        ensure!(
            self.spec.num_classes <= self.spec.c_max,
            "variant spec packs {} classes into {} channel rows — batched \
             logits would cross a block boundary",
            self.spec.num_classes,
            self.spec.c_max
        );
        ensure!(
            ct.c0.nq <= self.ctx.moduli.len()
                && ct.c0.limbs.iter().chain(ct.c1.limbs.iter()).all(|l| l.len() == self.ctx.n),
            "response ciphertext does not match the client's parameter chain"
        );
        ensure!(
            ct.c0.is_reduced(&self.ctx) && ct.c1.is_reduced(&self.ctx),
            "response ciphertext residues are not reduced modulo the chain"
        );
        let pt = encrypt::decrypt(&self.ctx, &self.sk, ct);
        let slots = self.encoder.decode(&self.ctx, &pt);
        let block = layout.block();
        Ok((0..batch)
            .map(|b| {
                (0..self.spec.num_classes)
                    .map(|m| slots[b * block + m * self.spec.t])
                    .collect()
            })
            .collect())
    }

    /// The client side of one interactive refresh round (DESIGN.md S21):
    /// validate the masked ciphertext against this client's chain,
    /// decrypt and decode it **at its own scale**, then re-encode at the
    /// chain's base scale and re-encrypt at the chain top
    /// ([`VariantSpec::input_limbs`]). The server's additive mask rides
    /// through both halves untouched, so this function only ever sees
    /// `m + r` — never the bare intermediate `m`. Draws from the same
    /// session RNG as clip encryption (the stale-key-file replay caveat
    /// of [`ClientKeys::encrypt_clip`] applies here too).
    pub fn refresh_ct(&self, ct: &Ciphertext) -> Result<Ciphertext> {
        ensure!(
            ct.c0.nq >= 1
                && ct.c0.nq <= self.ctx.moduli.len()
                && ct.c1.nq == ct.c0.nq
                && ct.c0.limbs.iter().chain(ct.c1.limbs.iter()).all(|l| l.len() == self.ctx.n),
            "refresh ciphertext does not match the client's parameter chain"
        );
        ensure!(
            ct.c0.is_reduced(&self.ctx) && ct.c1.is_reduced(&self.ctx),
            "refresh ciphertext residues are not reduced modulo the chain"
        );
        ensure!(
            ct.scale.is_finite() && ct.scale > 0.0,
            "refresh ciphertext scale must be finite and positive"
        );
        let pt = encrypt::decrypt(&self.ctx, &self.sk, ct);
        let slots = self.encoder.decode(&self.ctx, &pt);
        let fresh = self
            .encoder
            .encode(&self.ctx, &slots, self.ctx.scale, self.spec.input_limbs());
        let mut rng = self.rng.lock().unwrap();
        Ok(encrypt::encrypt(&self.ctx, &self.pk, &fresh, &mut *rng))
    }

    /// `decrypt_logits`' decision sibling (DESIGN.md S20): decrypt a
    /// decision-mode response and read the typed decision. `mode` is the
    /// output mode the request bundle carried (the server echoes it in
    /// the `NET_DECISION` frame); the decision circuit keeps the logits'
    /// slot layout, so the same extractor reads the indicator values and
    /// [`decide`] maps them to the decision. On a `Logits` mode this
    /// passes the raw scores through.
    pub fn decrypt_decision(&self, ct: &Ciphertext, mode: OutputMode) -> Result<Decision> {
        Ok(self.decrypt_decision_batch(ct, 1, mode)?.remove(0))
    }

    /// Per-clip decisions of a slot-batched decision-mode response.
    pub fn decrypt_decision_batch(
        &self,
        ct: &Ciphertext,
        batch: usize,
        mode: OutputMode,
    ) -> Result<Vec<Decision>> {
        Ok(self
            .decrypt_logits_batch(ct, batch)?
            .into_iter()
            .map(|v| decide(&v, mode))
            .collect())
    }
}

impl WireSerialize for ClientKeys {
    const KIND: u8 = KIND_CLIENT_KEYS;

    fn write_payload(&self, w: &mut ByteWriter) {
        w.put_str(&self.variant);
        CkksParams::write_payload(&self.params, w);
        for v in [
            self.spec.v,
            self.spec.c_in,
            self.spec.t,
            self.spec.c_max,
            self.spec.slots,
            self.spec.levels,
            self.spec.num_classes,
        ] {
            w.put_u64(v as u64);
        }
        w.put_u64_slice(&self.rng.lock().unwrap().state());
        write_poly(w, &self.sk.s);
        write_poly(w, &self.pk.b);
        write_poly(w, &self.pk.a);
    }

    fn read_payload(r: &mut ByteReader) -> Result<Self> {
        let variant = r.str()?;
        let params = CkksParams::read_payload(r)?;
        let mut dims = [0usize; 7];
        for d in dims.iter_mut() {
            *d = r.u64()? as usize;
        }
        let spec = VariantSpec {
            v: dims[0],
            c_in: dims[1],
            t: dims[2],
            c_max: dims[3],
            slots: dims[4],
            levels: dims[5],
            num_classes: dims[6],
        };
        // the checksum is integrity, not authenticity: implausible
        // dimensions must error here, not divide-by-zero in layout() or
        // index out of bounds in decrypt_logits
        let block = spec.c_max.checked_mul(spec.t);
        let clip_len = spec
            .v
            .checked_mul(spec.c_in)
            .and_then(|p| p.checked_mul(spec.t));
        let logit_top = spec
            .num_classes
            .checked_sub(1)
            .and_then(|m| m.checked_mul(spec.t));
        ensure!(
            spec.v >= 1
                && spec.c_in >= 1
                && spec.num_classes >= 1
                && block.is_some_and(|b| b >= 1 && b <= spec.slots)
                && clip_len.is_some()
                && logit_top.is_some_and(|i| i < spec.slots),
            "client key file: implausible variant spec dimensions"
        );
        let state = r.vec_u64(4)?;
        // xoshiro's all-zero state is a fixed point emitting zeros
        // forever — a tampered file must not silently destroy the
        // encryption randomness (same guard as keygen_with_state)
        ensure!(
            state != [0u64; 4],
            "client key file: all-zero RNG state is invalid"
        );
        let s = read_poly(r)?;
        let b = read_poly(r)?;
        let a = read_poly(r)?;
        let ctx = params.build()?;
        ensure!(
            s.nq == ctx.moduli.len() && s.has_special && s.is_ntt,
            "client key file: secret key shape mismatch"
        );
        ensure!(
            b.nq == ctx.moduli.len() && a.nq == b.nq && !b.has_special && !a.has_special
                && b.is_ntt && a.is_ntt,
            "client key file: public key shape mismatch"
        );
        ensure!(
            s.limbs.iter().chain(b.limbs.iter()).chain(a.limbs.iter()).all(|l| l.len() == ctx.n),
            "client key file: key polynomial degree mismatch"
        );
        ensure!(
            s.is_reduced(&ctx) && b.is_reduced(&ctx) && a.is_reduced(&ctx),
            "client key file: key residues are not reduced modulo the chain"
        );
        ensure!(
            ctx.slots() == spec.slots && ctx.max_level() == spec.levels,
            "client key file: spec geometry disagrees with the parameter set"
        );
        let encoder = Encoder::new(ctx.n);
        Ok(ClientKeys {
            variant,
            spec,
            params,
            ctx,
            encoder,
            sk: SecretKey { s },
            pk: PublicKey { b, a },
            rng: Mutex::new(Rng::from_state([state[0], state[1], state[2], state[3]])),
        })
    }
}

/// Client-side keygen against a published variant: derive the serving
/// geometry and the plan's rotation set exactly as the server will
/// (`session_geometry` + `compile` are deterministic), then generate
/// keys. Returns the local secret half and the server-shippable
/// [`EvalKeySet`]. The u64 seed makes this the *reproducible* entry
/// point (tests, the bit-identity suite); deployments use
/// [`keygen_with_state`].
pub fn keygen(
    model: &StgcnModel,
    variant: &str,
    opts: PlanOptions,
    seed: u64,
) -> Result<(ClientKeys, EvalKeySet)> {
    keygen_with_rng(model, variant, opts, Rng::seed_from_u64(seed))
}

/// [`keygen`] seeded with full 256-bit generator state (e.g. four words
/// from the OS entropy device — the CLI default): a single u64 seed
/// caps the secret keyspace at 2^64.
pub fn keygen_with_state(
    model: &StgcnModel,
    variant: &str,
    opts: PlanOptions,
    state: [u64; 4],
) -> Result<(ClientKeys, EvalKeySet)> {
    ensure!(state != [0u64; 4], "all-zero generator state is invalid");
    keygen_with_rng(model, variant, opts, Rng::from_state(state))
}

fn keygen_with_rng(
    model: &StgcnModel,
    variant: &str,
    opts: PlanOptions,
    rng: Rng,
) -> Result<(ClientKeys, EvalKeySet)> {
    let (layout, params) = session_geometry(model, opts)?;
    let ctx = params.build().context("building CKKS context for keygen")?;
    let chain = PlanChain::from_ctx(&ctx);
    let plan = compile(model, layout, &chain, opts)?;
    // Batched keygen ships Galois keys for the union of the batched and
    // single-clip plans: block-closed plans add wrap steps but also drop
    // the d·T rotations of all-wrapping diagonals, so neither rotation
    // set contains the other — and a tenant with batched keys must still
    // be able to send plain single-clip requests. Every batch size > 1
    // shares one rotation set (only the masks depend on the size), so
    // keys cut for one batched plan cover all ragged sizes too.
    let mut rots: std::collections::BTreeSet<usize> =
        plan.required_rotations().into_iter().collect();
    if opts.batch > 1 {
        let single = compile(model, layout, &chain, PlanOptions { batch: 1, ..opts })?;
        rots.extend(single.required_rotations());
    }
    let rots: Vec<usize> = rots.into_iter().collect();
    let spec = VariantSpec::for_model(model, &layout, &params);
    ClientKeys::generate_with_ctx(variant, spec, params, ctx, &rots, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn tiny() -> StgcnModel {
        StgcnModel::synthetic(Graph::ring(5), 8, 2, 3, &[4, 4], 3, 9)
    }

    #[test]
    fn test_client_keys_file_roundtrip_preserves_crypto() {
        let model = tiny();
        let (client, _ks) = keygen(&model, "v", PlanOptions::default(), 77).unwrap();
        let bytes = client.to_bytes();
        let back = ClientKeys::from_bytes(&bytes).unwrap();
        assert_eq!(client.variant, back.variant);
        assert_eq!(client.spec, back.spec);
        assert_eq!(client.params, back.params);
        // same rng state → the reloaded client encrypts identical bits
        let x: Vec<f64> = (0..model.v() * model.c_in * model.t)
            .map(|i| (i as f64) / 100.0)
            .collect();
        let a = client.encrypt_clip(&x).unwrap();
        let b = back.encrypt_clip(&x).unwrap();
        assert_eq!(a, b);
        // and decrypts what the original encrypted
        let ct = &a[0];
        assert_eq!(
            client.decrypt_logits(ct).unwrap(),
            back.decrypt_logits(ct).unwrap()
        );
    }

    #[test]
    fn test_clip_shape_is_checked() {
        let model = tiny();
        let (client, _) = keygen(&model, "v", PlanOptions::default(), 1).unwrap();
        assert!(client.encrypt_clip(&[0.0; 3]).is_err());
    }

    #[test]
    fn test_decision_opts_grow_the_keygen_chain() {
        // keygen derives the chain from session_geometry, which accounts
        // the decision circuit's levels — argmax keys get a deeper chain
        // than logits keys for the same model, without any keygen change
        let model = tiny();
        let (_, p_logits) = session_geometry(&model, PlanOptions::default()).unwrap();
        let opts = PlanOptions {
            output_mode: OutputMode::Argmax,
            ..PlanOptions::default()
        };
        let (_, p_argmax) = session_geometry(&model, opts).unwrap();
        assert!(
            p_argmax.levels > p_logits.levels,
            "argmax chain {} must be deeper than logits chain {}",
            p_argmax.levels,
            p_logits.levels
        );
    }

    #[test]
    fn test_refresh_ct_preserves_values_and_lands_at_the_chain_top() {
        let model = tiny();
        let (client, _) = keygen(&model, "v", PlanOptions::default(), 5).unwrap();
        let n = model.v() * model.c_in * model.t;
        let x: Vec<f64> = (0..n).map(|i| ((i * 13 % 37) as f64 - 18.0) / 40.0).collect();
        let cts = client.encrypt_clip(&x).unwrap();
        let fresh = client.refresh_ct(&cts[0]).unwrap();
        // back at the full chain, base scale
        assert_eq!(fresh.c0.nq, client.spec.input_limbs());
        // same slot contents through the shared logits extractor
        let a = client.decrypt_logits(&cts[0]).unwrap();
        let b = client.decrypt_logits(&fresh).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        // fresh randomness: the re-encryption is not a byte replay
        assert_ne!(fresh, cts[0]);
    }

    #[test]
    fn test_corrupt_client_key_file_rejected() {
        let model = tiny();
        let (client, _) = keygen(&model, "v", PlanOptions::default(), 2).unwrap();
        let bytes = client.to_bytes();
        for pos in (0..bytes.len()).step_by(131) {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(ClientKeys::from_bytes(&bad).is_err(), "flip at {pos}");
        }
        assert!(ClientKeys::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    }
}
