//! Binary framing for the wire formats (DESIGN.md S15).
//!
//! Every serialized object is one **frame**:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"LGWR"
//! 4       2     format version (little-endian u16)
//! 6       1     record kind (one of the KIND_* constants)
//! 7       1     reserved, must be zero
//! 8       8     payload length (little-endian u64)
//! 16      len   payload
//! 16+len  8     FNV-1a 64 checksum over bytes [0, 16+len)
//! ```
//!
//! The checksum covers header *and* payload and is verified before a
//! single payload byte is parsed, so truncation and bit flips anywhere in
//! the frame surface as `Err` — decoding never panics and never allocates
//! from unvalidated lengths. All integers are little-endian; `f64`s travel
//! as their exact IEEE-754 bit patterns (the same lossless discipline as
//! `HePlan::to_text`).

use anyhow::{bail, ensure, Result};

/// Frame magic: "LinGcn WiRe".
pub const MAGIC: [u8; 4] = *b"LGWR";
/// Wire format version written by this build. v2: `CtBundle` carries a
/// slot-batch size (DESIGN.md S16). v3: `CtBundle` carries the requested
/// output mode (DESIGN.md S20).
pub const VERSION: u16 = 3;
/// Oldest version still readable. Only the `CtBundle` payload changed in
/// v2 and v3, so v1 frames of every *other* kind (client key files,
/// eval-key bundles, ciphertexts, params) stay readable — a pre-batching
/// tenant's persisted secret key must not become undecodable on upgrade.
pub const MIN_VERSION: u16 = 1;

/// Fixed frame header size (magic + version + kind + reserved + length).
/// Public because the TCP tier ([`super::net`]) reads headers incrementally
/// off a socket to validate length budgets *before* allocating payloads.
pub const HEADER_LEN: usize = 16;
/// Trailing FNV-1a 64 checksum size.
pub const CHECKSUM_LEN: usize = 8;

/// Record kinds (one per serializable type).
pub const KIND_PARAMS: u8 = 1;
pub const KIND_PUBLIC_KEY: u8 = 2;
pub const KIND_KSWITCH_KEY: u8 = 3;
pub const KIND_EVAL_KEY_SET: u8 = 4;
pub const KIND_CIPHERTEXT: u8 = 5;
pub const KIND_CT_BUNDLE: u8 = 6;
pub const KIND_CLIENT_KEYS: u8 = 7;

/// TCP protocol kinds (DESIGN.md S18). Kinds 8..16 stay reserved for
/// future *at-rest* record types; the socket vocabulary starts at 16 so
/// the two families are visually distinct in hex dumps. These frames only
/// ever travel over a connection — they are never persisted.
pub const KIND_NET_HELLO: u8 = 16;
pub const KIND_NET_OK: u8 = 17;
pub const KIND_NET_ERROR: u8 = 18;
pub const KIND_NET_REGISTER: u8 = 19;
pub const KIND_NET_INFER: u8 = 20;
pub const KIND_NET_LOGITS: u8 = 21;
/// Observability probe (DESIGN.md S19): empty request payload, JSON
/// snapshot reply. Served off the metrics/plan-cache state only — never
/// touches the HE pipeline.
pub const KIND_NET_STATUS: u8 = 22;
/// Decision-mode response (DESIGN.md S20): same ciphertext payload shape
/// as `KIND_NET_LOGITS` plus the output-mode triple the plan evaluated,
/// so a client can't silently misread an argmax indicator as raw scores.
pub const KIND_NET_DECISION: u8 = 23;
/// Mid-inference refresh request, server → client (DESIGN.md S21): the
/// session token, the 0-based round index, and the masked level-0
/// ciphertexts the client must decrypt and re-encrypt at top level.
/// Arrives on the *same* connection as the in-flight `KIND_NET_INFER`,
/// between that request and its response — the first stateful exchange in
/// the protocol.
pub const KIND_NET_REFRESH_REQ: u8 = 24;
/// The client's answer to `KIND_NET_REFRESH_REQ`: the echoed session
/// token + round index and the fresh top-level ciphertexts, in request
/// order. A token/round mismatch or malformed geometry is rejected typed
/// (`NET_ERROR`), never panics the handler.
pub const KIND_NET_REFRESH_RESP: u8 = 25;

/// FNV-1a 64-bit over a byte slice (integrity only — tamper *detection*,
/// not authentication; see the threat model in DESIGN.md S15).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Wrap a payload in a checksummed frame.
pub fn frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + CHECKSUM_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind);
    out.push(0);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let sum = fnv1a64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Like [`frame`], but the payload is written straight into the frame
/// buffer (header first, length backpatched, checksum appended) — no
/// intermediate payload copy. This matters on the serving path, where
/// ciphertext bundles are tens of MiB at paper scale.
pub fn frame_with(kind: u8, write_payload: impl FnOnce(&mut ByteWriter)) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.push(kind);
    buf.push(0);
    buf.extend_from_slice(&0u64.to_le_bytes()); // length backpatched below
    let mut w = ByteWriter { buf };
    write_payload(&mut w);
    let mut buf = w.buf;
    let payload_len = (buf.len() - HEADER_LEN) as u64;
    buf[8..16].copy_from_slice(&payload_len.to_le_bytes());
    let sum = fnv1a64(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    buf
}

/// Verify a frame's header and checksum and return its payload slice.
/// Rejects wrong magic/version/kind, reserved-byte damage, length
/// mismatches (truncation or padding), and any checksum failure.
pub fn unframe(expected_kind: u8, bytes: &[u8]) -> Result<&[u8]> {
    ensure!(
        bytes.len() >= HEADER_LEN + CHECKSUM_LEN,
        "wire frame too short ({} bytes)",
        bytes.len()
    );
    ensure!(bytes[0..4] == MAGIC, "wire frame magic mismatch");
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "unsupported wire version {version}"
    );
    let kind = bytes[6];
    // the one payload whose shape changed in v2 (slot-batch field) and
    // again in v3 (output-mode triple): old bundles would mis-parse the
    // new fields as the ciphertext count
    ensure!(
        !(version < 3 && kind == KIND_CT_BUNDLE),
        "pre-v3 ciphertext bundles are not readable by the decision-mode \
         (v3) format — re-encrypt the request"
    );
    ensure!(
        kind == expected_kind,
        "wire record kind mismatch: expected {expected_kind}, got {kind}"
    );
    ensure!(bytes[7] == 0, "wire frame reserved byte damaged");
    let len = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let expected_total = (HEADER_LEN as u64)
        .checked_add(len)
        .and_then(|v| v.checked_add(CHECKSUM_LEN as u64));
    match expected_total {
        Some(total) if total == bytes.len() as u64 => {}
        _ => bail!(
            "wire frame length mismatch: header says {len} payload bytes, \
             frame is {} bytes",
            bytes.len()
        ),
    }
    let body_end = bytes.len() - CHECKSUM_LEN;
    let want = u64::from_le_bytes(bytes[body_end..].try_into().unwrap());
    let got = fnv1a64(&bytes[..body_end]);
    ensure!(got == want, "wire frame checksum mismatch (tampered or corrupt)");
    Ok(&bytes[HEADER_LEN..body_end])
}

/// Append-only payload writer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new() }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// f64 as its exact bit pattern.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_u64_slice(&mut self, vs: &[u64]) {
        for &v in vs {
            self.put_u64(v);
        }
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked payload reader: every accessor returns `Err` past the
/// end, and vector reads validate the byte budget *before* allocating.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn need(&self, n: usize) -> Result<()> {
        ensure!(
            self.remaining() >= n,
            "wire payload truncated: need {n} bytes, {} left",
            self.remaining()
        );
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        self.need(n)?;
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// A u8 that must be 0 or 1.
    pub fn flag(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("wire payload: flag byte must be 0/1, got {other}"),
        }
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|_| anyhow::anyhow!("wire payload: invalid UTF-8 string"))?
            .to_string())
    }

    pub fn vec_u64(&mut self, count: usize) -> Result<Vec<u64>> {
        let nbytes = count.checked_mul(8).ok_or_else(|| {
            anyhow::anyhow!("wire payload: u64 vector length overflows")
        })?;
        // one bounds check + bulk decode: this path carries the MiB-scale
        // ciphertext limbs and key bundles
        let bytes = self.take(nbytes)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// The payload must be fully consumed (trailing garbage is tampering).
    pub fn finish(&self) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "wire payload has {} trailing bytes",
            self.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_frame_roundtrip() {
        let payload = b"hello wire".to_vec();
        let f = frame(KIND_PARAMS, &payload);
        assert_eq!(unframe(KIND_PARAMS, &f).unwrap(), payload.as_slice());
    }

    #[test]
    fn test_frame_with_matches_frame() {
        // the zero-copy framing must be byte-identical to the two-step one
        let payload = b"abc123xyz".to_vec();
        let a = frame(KIND_CT_BUNDLE, &payload);
        let b = frame_with(KIND_CT_BUNDLE, |w| {
            for &x in &payload {
                w.put_u8(x);
            }
        });
        assert_eq!(a, b);
        assert_eq!(unframe(KIND_CT_BUNDLE, &b).unwrap(), payload.as_slice());
    }

    #[test]
    fn test_every_bit_flip_is_rejected() {
        let f = frame(KIND_CIPHERTEXT, &[1, 2, 3, 4, 5, 6, 7, 8]);
        for byte in 0..f.len() {
            for bit in 0..8 {
                let mut bad = f.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    unframe(KIND_CIPHERTEXT, &bad).is_err(),
                    "flip at byte {byte} bit {bit} must be rejected"
                );
            }
        }
    }

    #[test]
    fn test_truncation_and_extension_rejected() {
        let f = frame(KIND_PUBLIC_KEY, &vec![0xAB; 64]);
        for cut in [0, 1, 15, 16, 24, f.len() - 1] {
            assert!(unframe(KIND_PUBLIC_KEY, &f[..cut]).is_err(), "cut {cut}");
        }
        let mut long = f.clone();
        long.push(0);
        assert!(unframe(KIND_PUBLIC_KEY, &long).is_err());
    }

    #[test]
    fn test_kind_mismatch_rejected() {
        let f = frame(KIND_PARAMS, b"x");
        assert!(unframe(KIND_PUBLIC_KEY, &f).is_err());
    }

    /// Re-frame a payload under an explicit version (checksum rebuilt).
    fn frame_v(version: u16, kind: u8, payload: &[u8]) -> Vec<u8> {
        let mut f = frame(kind, payload);
        f[4..6].copy_from_slice(&version.to_le_bytes());
        let body_end = f.len() - 8;
        let sum = fnv1a64(&f[..body_end]);
        let at = body_end;
        f[at..].copy_from_slice(&sum.to_le_bytes());
        f
    }

    #[test]
    fn test_version_window() {
        let payload = b"legacy".to_vec();
        // v1/v2 frames stay readable for kinds whose payload never changed
        let v1 = frame_v(1, KIND_CLIENT_KEYS, &payload);
        assert_eq!(unframe(KIND_CLIENT_KEYS, &v1).unwrap(), payload.as_slice());
        let v2 = frame_v(2, KIND_CLIENT_KEYS, &payload);
        assert_eq!(unframe(KIND_CLIENT_KEYS, &v2).unwrap(), payload.as_slice());
        // ...but not for the bundle kind, whose payload grew a field in
        // v2 (slot batch) and again in v3 (output mode)
        for old in [1u16, 2] {
            let bundle = frame_v(old, KIND_CT_BUNDLE, &payload);
            assert!(unframe(KIND_CT_BUNDLE, &bundle).is_err(), "v{old} bundle");
        }
        // versions outside the window are rejected either side
        assert!(unframe(KIND_CLIENT_KEYS, &frame_v(0, KIND_CLIENT_KEYS, &payload)).is_err());
        assert!(unframe(KIND_CLIENT_KEYS, &frame_v(4, KIND_CLIENT_KEYS, &payload)).is_err());
    }

    #[test]
    fn test_reader_bounds() {
        let mut w = ByteWriter::new();
        w.put_u32(7);
        w.put_str("ok");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.str().unwrap(), "ok");
        r.finish().unwrap();
        assert!(r.u8().is_err(), "reading past the end must error");

        // a huge claimed vector length must fail before allocating
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let n = r.u64().unwrap() as usize;
        assert!(ByteReader::new(&bytes[8..]).vec_u64(n).is_err());
    }
}
