//! TCP serving tier (DESIGN.md S18): the wire subsystem on a real socket.
//!
//! A connection is a sequence of the same length-prefixed, checksummed
//! [`codec`](super::codec) frames the at-rest formats use, with a small
//! socket-only vocabulary (`KIND_NET_*`). The shape every session follows:
//!
//! ```text
//! client                                server
//!   NET_HELLO {proto, tenant}  ──────▶   validate + connection admission
//!   ◀───────────────  NET_OK | NET_ERROR(over-quota/protocol/bad-frame)
//!   NET_REGISTER {EvalKeySet}  ──────▶   KeyRegistry::register
//!   ◀───────────────  NET_OK | NET_ERROR(rejected/bad-frame)
//!   NET_INFER {variant, hash, batch, n}  ─▶  admission (tenant known?
//!   CIPHERTEXT × n  ───(streamed)────▶     in-flight quota?) *before*
//!                                          ingesting a single ciphertext;
//!                                          each frame validated on arrival
//!   ◀──────────  NET_LOGITS {variant, timings, ct} | NET_ERROR
//! ```
//!
//! Ciphertext uploads are **streamed**: the server reads one frame at a
//! time straight into the validator (`Ciphertext::from_bytes`) and never
//! buffers a whole request — a paper-scale bundle is tens of MiB, and a
//! hostile length prefix must be rejected *before* any allocation.
//!
//! The server is thread-per-connection over the existing coordinator
//! (leader/batcher/worker) pipeline: handler threads block in
//! [`Coordinator::infer_blocking_encrypted`], so slot-batching across
//! tenants keeps working unchanged. [`NetBackend`] decouples the socket
//! machinery from the HE stack so the fault-injection suite
//! (`rust/tests/net_faults.rs`) runs in debug builds against mock
//! backends; `rust/tests/net_roundtrip.rs` proves the real path produces
//! logits bit-identical to the in-process [`WireExecutor`] on the same
//! bundles.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::ckks::Ciphertext;
use crate::coordinator::{Coordinator, Metrics};
use crate::he_infer::{OutputMode, RefreshSource};
use crate::wire::client::ClientKeys;
use crate::wire::codec::{
    frame_with, unframe, ByteReader, CHECKSUM_LEN, HEADER_LEN, KIND_CIPHERTEXT,
    KIND_NET_DECISION, KIND_NET_ERROR, KIND_NET_HELLO, KIND_NET_INFER, KIND_NET_LOGITS,
    KIND_NET_OK, KIND_NET_REFRESH_REQ, KIND_NET_REFRESH_RESP, KIND_NET_REGISTER,
    KIND_NET_STATUS, MAGIC, MIN_VERSION, VERSION,
};
use crate::wire::format::{
    read_output_mode, write_output_mode, CtBundle, EvalKeySet, WireSerialize, MAX_BATCH,
};
use crate::wire::server::WireExecutor;

/// Protocol revision carried in the hello frame; bumped independently of
/// the codec version when the *conversation shape* changes.
pub const NET_PROTO: u32 = 1;

/// Typed error codes carried in `NET_ERROR` frames. The vendored anyhow
/// shim has no downcasting, so the stable contract tests (and clients)
/// key on is the [`err_name`] token embedded in the error message.
pub const ERR_BAD_FRAME: u32 = 1;
pub const ERR_TOO_LARGE: u32 = 2;
pub const ERR_PROTOCOL: u32 = 3;
pub const ERR_UNKNOWN_TENANT: u32 = 4;
pub const ERR_OVER_QUOTA: u32 = 5;
pub const ERR_REJECTED: u32 = 6;
pub const ERR_TIMEOUT: u32 = 7;
pub const ERR_INTERNAL: u32 = 8;
/// The request asked for an output mode the server's plans were not
/// compiled for (DESIGN.md S20). Refused at the `NET_INFER` header —
/// announced ciphertext frames are drained so the connection stays in
/// sync — and never silently served with a different output shape.
pub const ERR_MODE_MISMATCH: u32 = 9;

/// Stable text token for an error code (part of the wire contract: the
/// fault suites assert on these substrings).
pub fn err_name(code: u32) -> &'static str {
    match code {
        ERR_BAD_FRAME => "bad-frame",
        ERR_TOO_LARGE => "too-large",
        ERR_PROTOCOL => "protocol",
        ERR_UNKNOWN_TENANT => "unknown-tenant",
        ERR_OVER_QUOTA => "over-quota",
        ERR_REJECTED => "rejected",
        ERR_TIMEOUT => "timeout",
        ERR_INTERNAL => "internal",
        ERR_MODE_MISMATCH => "mode-mismatch",
        _ => "unknown",
    }
}

/// Server-side knobs. `Duration::ZERO` timeouts and `0` quotas mean
/// "unlimited" (useful in tests; production defaults are all bounded).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Per-read socket timeout — a stalled or slow-writing client is cut
    /// off with a typed `timeout` error.
    pub read_timeout: Duration,
    /// Per-write socket timeout.
    pub write_timeout: Duration,
    /// Payload budget for ciphertext and control frames. Enforced from
    /// the 16-byte header alone, before any payload allocation.
    pub max_frame_bytes: u64,
    /// Payload budget for `NET_REGISTER` frames (an eval-key bundle is an
    /// order of magnitude bigger than a ciphertext).
    pub max_key_frame_bytes: u64,
    /// Most ciphertext frames one `NET_INFER` may announce.
    pub max_request_cts: usize,
    /// Per-tenant cap on simultaneously open connections (checked at
    /// hello).
    pub max_conns_per_tenant: usize,
    /// Per-tenant cap on requests simultaneously inside the coordinator
    /// (checked at the `NET_INFER` header, before ciphertext ingest).
    pub max_inflight_per_tenant: usize,
    /// Server-side ceiling on interactive refresh rounds per request
    /// (DESIGN.md S21). The effective session budget is the client's
    /// announced `max_rounds` clamped to this; `0` leaves the client's
    /// announcement unclamped.
    pub max_refresh_rounds: u32,
    /// Per-tenant cap on refresh rounds simultaneously in flight across
    /// all of the tenant's connections (checked at each round, server
    /// side; an over-quota round fails that inference typed without
    /// desyncing its socket).
    pub max_rounds_inflight_per_tenant: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
            max_frame_bytes: 256 << 20,
            max_key_frame_bytes: 1 << 30,
            max_request_cts: 4096, // mirrors CtBundle's own count bound
            max_conns_per_tenant: 64,
            max_inflight_per_tenant: 32,
            max_refresh_rounds: 16,
            max_rounds_inflight_per_tenant: 8,
        }
    }
}

/// What an inference produced, plus the server-side timing split the
/// logits frame carries back to the client.
#[derive(Clone, Debug)]
pub struct InferOutcome {
    pub variant: String,
    pub ct_logits: Ciphertext,
    pub queue: Duration,
    pub exec: Duration,
}

/// The server's view of the HE stack. Production is
/// [`CoordinatorBackend`]; the fault suite substitutes mocks so socket
/// behavior is testable in debug builds without real CKKS inference.
pub trait NetBackend: Send + Sync + 'static {
    fn register(&self, tenant: &str, key_set: EvalKeySet) -> Result<()>;
    /// Admission pre-check: is this tenant known? Consulted at the
    /// `NET_INFER` header so an unknown tenant is refused *before* the
    /// server ingests its ciphertexts.
    fn is_registered(&self, tenant: &str) -> bool;
    #[allow(clippy::too_many_arguments)]
    fn infer(
        &self,
        tenant: &str,
        variant: Option<String>,
        cts: Vec<Ciphertext>,
        params_hash: Option<u64>,
        batch: usize,
        mode: OutputMode,
    ) -> Result<InferOutcome>;
    /// [`NetBackend::infer`] with an interactive refresh bridge
    /// (DESIGN.md S21): requests that announced a refresh budget hand the
    /// per-connection [`RefreshSource`] in here so refresh-bearing plans
    /// can round-trip level-0 intermediates to the client mid-execution.
    /// Default: ignore the bridge and serve non-interactively — mocks
    /// inherit it and compile unchanged (a refresh-bearing plan then
    /// fails typed inside the executor, never silently).
    #[allow(clippy::too_many_arguments)]
    fn infer_rounds(
        &self,
        tenant: &str,
        variant: Option<String>,
        cts: Vec<Ciphertext>,
        params_hash: Option<u64>,
        batch: usize,
        mode: OutputMode,
        rounds: Option<Arc<dyn RefreshSource>>,
    ) -> Result<InferOutcome> {
        let _ = rounds;
        self.infer(tenant, variant, cts, params_hash, batch, mode)
    }
    /// The output mode this backend's plans are compiled to answer with
    /// (DESIGN.md S20). Consulted at the `NET_INFER` header so a request
    /// for any other mode is refused *before* ciphertext ingest. Default:
    /// logits — mocks inherit it and compile unchanged.
    fn output_mode(&self) -> OutputMode {
        OutputMode::Logits
    }
    /// Backend-specific slice of the `NET_STATUS` snapshot (the production
    /// backend reports its plan-cache contents). Empty string = omit the
    /// `"backend"` key; mocks inherit this default and compile unchanged.
    fn status_json(&self) -> String {
        String::new()
    }
}

/// The production backend: key registration goes straight to the
/// [`WireExecutor`]'s registry, inference through the coordinator's
/// leader/batcher/worker pipeline (so cross-tenant slot batching and all
/// serving metrics keep working over TCP).
pub struct CoordinatorBackend {
    executor: Arc<WireExecutor>,
    coordinator: Coordinator,
}

impl CoordinatorBackend {
    pub fn new(executor: Arc<WireExecutor>, coordinator: Coordinator) -> Self {
        CoordinatorBackend { executor, coordinator }
    }
}

impl NetBackend for CoordinatorBackend {
    fn register(&self, tenant: &str, key_set: EvalKeySet) -> Result<()> {
        self.executor.register(tenant, key_set).map(|_| ())
    }

    fn is_registered(&self, tenant: &str) -> bool {
        self.executor.registry.contains(tenant)
    }

    fn infer(
        &self,
        tenant: &str,
        variant: Option<String>,
        cts: Vec<Ciphertext>,
        params_hash: Option<u64>,
        batch: usize,
        mode: OutputMode,
    ) -> Result<InferOutcome> {
        self.infer_rounds(tenant, variant, cts, params_hash, batch, mode, None)
    }

    fn infer_rounds(
        &self,
        tenant: &str,
        variant: Option<String>,
        cts: Vec<Ciphertext>,
        params_hash: Option<u64>,
        batch: usize,
        mode: OutputMode,
        rounds: Option<Arc<dyn RefreshSource>>,
    ) -> Result<InferOutcome> {
        let resp = self.coordinator.infer_blocking_encrypted_rounds(
            tenant.to_string(),
            variant,
            cts,
            params_hash,
            batch,
            mode,
            rounds,
            None,
        )?;
        if let Some(e) = resp.error {
            bail!("{e}");
        }
        let ct_logits = resp
            .ct_logits
            .ok_or_else(|| anyhow!("coordinator returned neither logits nor an error"))?;
        Ok(InferOutcome { variant: resp.variant, ct_logits, queue: resp.queue, exec: resp.exec })
    }

    fn output_mode(&self) -> OutputMode {
        self.executor.output_mode()
    }

    fn status_json(&self) -> String {
        self.executor.status_json()
    }
}

// ---------------------------------------------------------------------------
// frame builders / parsers (shared by server, client, and the raw-socket
// fault suite — public so tests can speak the protocol byte-for-byte)
// ---------------------------------------------------------------------------

pub fn hello_frame(tenant: &str) -> Vec<u8> {
    frame_with(KIND_NET_HELLO, |w| {
        w.put_u32(NET_PROTO);
        w.put_str(tenant);
    })
}

pub fn ok_frame(message: &str) -> Vec<u8> {
    frame_with(KIND_NET_OK, |w| w.put_str(message))
}

pub fn error_frame(code: u32, message: &str) -> Vec<u8> {
    frame_with(KIND_NET_ERROR, |w| {
        w.put_u32(code);
        w.put_str(message);
    })
}

/// The `NET_STATUS` probe: an empty payload — everything the server
/// needs it already has.
pub fn status_frame() -> Vec<u8> {
    frame_with(KIND_NET_STATUS, |_w| {})
}

fn parse_status_request(frame: &[u8]) -> Result<()> {
    let payload = unframe(KIND_NET_STATUS, frame)?;
    ensure!(payload.is_empty(), "status request carries no payload");
    Ok(())
}

/// Extract the JSON document from a `NET_STATUS` reply.
pub fn parse_status_frame(frame: &[u8]) -> Result<String> {
    let payload = unframe(KIND_NET_STATUS, frame)?;
    let mut r = ByteReader::new(payload);
    let json = r.str()?;
    r.finish()?;
    Ok(json)
}

/// The `NET_INFER` header announcing a streamed upload of `ct_count`
/// ciphertext frames. `mode` is the output mode the client requests
/// (DESIGN.md S20) — checked against the server's compiled plans at
/// admission, before any ciphertext is ingested. Announces no refresh
/// budget (`max_rounds = 0`): the server must answer without interactive
/// rounds or fail typed.
pub fn infer_header_frame(
    variant: Option<&str>,
    params_hash: Option<u64>,
    batch: usize,
    mode: OutputMode,
    ct_count: usize,
) -> Vec<u8> {
    infer_header_frame_rounds(variant, params_hash, batch, mode, ct_count, 0)
}

/// [`infer_header_frame`] with an interactive refresh budget
/// (DESIGN.md S21): `max_rounds > 0` tells the server this client will
/// answer up to that many `REFRESH_REQ` round trips mid-inference. The
/// budget travels as a trailing field the parser treats as optional, so
/// pre-S21 headers keep parsing (as `max_rounds = 0`) without a codec
/// version bump.
pub fn infer_header_frame_rounds(
    variant: Option<&str>,
    params_hash: Option<u64>,
    batch: usize,
    mode: OutputMode,
    ct_count: usize,
    max_rounds: u32,
) -> Vec<u8> {
    frame_with(KIND_NET_INFER, |w| {
        w.put_str(variant.unwrap_or(""));
        w.put_u8(params_hash.is_some() as u8);
        w.put_u64(params_hash.unwrap_or(0));
        w.put_u64(batch as u64);
        write_output_mode(w, mode);
        w.put_u32(ct_count as u32);
        w.put_u32(max_rounds);
    })
}

pub fn parse_error_frame(frame: &[u8]) -> Result<(u32, String)> {
    let payload = unframe(KIND_NET_ERROR, frame)?;
    let mut r = ByteReader::new(payload);
    let code = r.u32()?;
    let message = r.str()?;
    r.finish()?;
    Ok((code, message))
}

fn parse_ok_frame(frame: &[u8]) -> Result<String> {
    let payload = unframe(KIND_NET_OK, frame)?;
    let mut r = ByteReader::new(payload);
    let message = r.str()?;
    r.finish()?;
    Ok(message)
}

/// Tenant ids end up as registry and batch-queue keys; keep them short,
/// non-empty and free of control characters (the coordinator's composite
/// queue keys use `'\u{1}'` as a separator).
pub fn validate_tenant(tenant: &str) -> Result<()> {
    ensure!(
        !tenant.is_empty() && tenant.len() <= 128,
        "tenant id must be 1..=128 bytes"
    );
    ensure!(
        tenant.chars().all(|c| !c.is_control()),
        "tenant id must not contain control characters"
    );
    Ok(())
}

fn parse_hello(frame: &[u8]) -> Result<(u32, String)> {
    let payload = unframe(KIND_NET_HELLO, frame)?;
    let mut r = ByteReader::new(payload);
    let proto = r.u32()?;
    let tenant = r.str()?;
    r.finish()?;
    validate_tenant(&tenant)?;
    Ok((proto, tenant))
}

fn parse_register(frame: &[u8]) -> Result<EvalKeySet> {
    let payload = unframe(KIND_NET_REGISTER, frame)?;
    let mut r = ByteReader::new(payload);
    let key_set = EvalKeySet::read_payload(&mut r)?;
    r.finish()?;
    Ok(key_set)
}

struct InferHeader {
    variant: Option<String>,
    params_hash: Option<u64>,
    batch: usize,
    mode: OutputMode,
    ct_count: usize,
    /// Interactive refresh rounds the client is willing to answer
    /// (DESIGN.md S21); `0` = the request must be served non-interactively.
    max_rounds: u32,
}

fn parse_infer_header(frame: &[u8], max_cts: usize) -> Result<InferHeader> {
    let payload = unframe(KIND_NET_INFER, frame)?;
    let mut r = ByteReader::new(payload);
    let variant = r.str()?;
    let has_hash = r.flag()?;
    let hash = r.u64()?;
    let batch = r.u64()? as usize;
    // a forged mode tag errors typed here, before the count is even read
    let mode = read_output_mode(&mut r)?;
    let ct_count = r.u32()? as usize;
    // tolerant trailing field: pre-S21 headers end at the count and parse
    // as "no refresh budget" — anything after the budget is still a fault
    let max_rounds = if r.remaining() > 0 { r.u32()? } else { 0 };
    r.finish()?;
    ensure!(
        (1..=MAX_BATCH).contains(&batch),
        "infer header: slot-batch size {batch} outside 1..={MAX_BATCH}"
    );
    ensure!(
        ct_count >= 1 && ct_count <= max_cts,
        "infer header: ciphertext count {ct_count} outside 1..={max_cts}"
    );
    Ok(InferHeader {
        variant: if variant.is_empty() { None } else { Some(variant) },
        params_hash: has_hash.then_some(hash),
        batch,
        mode,
        ct_count,
        max_rounds,
    })
}

// ---------------------------------------------------------------------------
// interactive refresh rounds (DESIGN.md S21)
// ---------------------------------------------------------------------------

/// Shared payload shape of the two refresh frames: `{token, round, n,
/// ciphertext × n}`. The token correlates every round of one inference;
/// the round index orders them — a response echoing either one wrong is
/// a stale/replayed round and fails the inference typed.
fn refresh_frame(kind: u8, token: u64, round: u32, cts: &[Ciphertext]) -> Vec<u8> {
    frame_with(kind, |w| {
        w.put_u64(token);
        w.put_u32(round);
        w.put_u32(cts.len() as u32);
        for ct in cts {
            ct.write_payload(w);
        }
    })
}

fn parse_refresh(kind: u8, frame: &[u8], max_cts: usize) -> Result<(u64, u32, Vec<Ciphertext>)> {
    let payload = unframe(kind, frame)?;
    let mut r = ByteReader::new(payload);
    let token = r.u64()?;
    let round = r.u32()?;
    let n = r.u32()? as usize;
    ensure!(
        n >= 1 && n <= max_cts,
        "refresh frame: ciphertext count {n} outside 1..={max_cts}"
    );
    let mut cts = Vec::with_capacity(n);
    for i in 0..n {
        // forged limb/shape/scale geometry errors typed inside the
        // ciphertext validator — it can never panic the handler
        cts.push(
            Ciphertext::read_payload(&mut r)
                .with_context(|| format!("refresh ciphertext {i}/{n}"))?,
        );
    }
    r.finish()?;
    Ok((token, round, cts))
}

/// Server → client: one mid-inference refresh round carrying the masked
/// level-0 intermediates (DESIGN.md S21 — the executor masked them
/// before they reached the wire).
pub fn refresh_req_frame(token: u64, round: u32, cts: &[Ciphertext]) -> Vec<u8> {
    refresh_frame(KIND_NET_REFRESH_REQ, token, round, cts)
}

/// Parse a `REFRESH_REQ` frame into `(token, round, masked cts)`.
pub fn parse_refresh_req(frame: &[u8], max_cts: usize) -> Result<(u64, u32, Vec<Ciphertext>)> {
    parse_refresh(KIND_NET_REFRESH_REQ, frame, max_cts)
}

/// Client → server: the answer to a `REFRESH_REQ` — the same
/// ciphertexts decrypted and re-encrypted at the chain top, echoing the
/// round's token and index.
pub fn refresh_resp_frame(token: u64, round: u32, cts: &[Ciphertext]) -> Vec<u8> {
    refresh_frame(KIND_NET_REFRESH_RESP, token, round, cts)
}

/// Parse a `REFRESH_RESP` frame into `(token, round, fresh cts)`. Public
/// for the fault corpus: a forged response must error typed, never panic
/// the handler thread.
pub fn parse_refresh_resp(frame: &[u8], max_cts: usize) -> Result<(u64, u32, Vec<Ciphertext>)> {
    parse_refresh(KIND_NET_REFRESH_RESP, frame, max_cts)
}

/// Session-token scrambler (splitmix64 finalizer). Tokens correlate the
/// `REFRESH_REQ`/`REFRESH_RESP` pairs of one inference; they are
/// sequence-unique per server, not secret — both directions ride the
/// same socket either way.
fn session_token(n: u64) -> u64 {
    let mut z = n.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// One refresh round-trip request from the executor's worker thread to
/// the connection handler: the masked level-0 ciphertexts plus the reply
/// channel the handler answers on.
struct RoundRequest {
    round: usize,
    masked: Vec<Ciphertext>,
    reply: mpsc::Sender<Result<Vec<Ciphertext>>>,
}

/// The wire tier's [`RefreshSource`] (DESIGN.md S21): each refresh call
/// crosses an mpsc pair to the connection handler thread, which owns the
/// socket and round-trips the batch to the client as one
/// `REFRESH_REQ`/`REFRESH_RESP` exchange. Transport only — the additive
/// mask is applied and removed inside the executor, so this bridge (and
/// the wire below it) only ever carries masked ciphertexts. Dropping the
/// handler's receiver fails every later round fast instead of hanging
/// the executor. The same interface an in-circuit CKKS bootstrap would
/// implement locally — swapping it in changes nothing above this line.
struct NetRefreshBridge {
    /// Mutex for `Sync` (rounds are sequential by construction — the
    /// interactive executor flushes one round at a time).
    tx: Mutex<mpsc::Sender<RoundRequest>>,
    /// Effective round budget: the client's announced `max_rounds`
    /// clamped by [`NetConfig::max_refresh_rounds`].
    max_rounds: u32,
}

impl RefreshSource for NetRefreshBridge {
    fn refresh(&self, masked: &[Ciphertext], round: usize) -> Result<Vec<Ciphertext>> {
        ensure!(
            (round as u64) < u64::from(self.max_rounds),
            "refresh round {round} exceeds the session budget of {} round(s) \
             (raise --allow-refresh)",
            self.max_rounds
        );
        let (reply_tx, reply_rx) = mpsc::channel();
        lock(&self.tx)
            .send(RoundRequest { round, masked: masked.to_vec(), reply: reply_tx })
            .map_err(|_| anyhow!("refresh round {round}: the connection handler is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("refresh round {round}: connection closed mid-round"))?
    }
}

fn logits_frame(out: &InferOutcome) -> Vec<u8> {
    frame_with(KIND_NET_LOGITS, |w| {
        w.put_str(&out.variant);
        w.put_u64(out.queue.as_micros() as u64);
        w.put_u64(out.exec.as_micros() as u64);
        out.ct_logits.write_payload(w);
    })
}

fn parse_logits_frame(frame: &[u8]) -> Result<InferOutcome> {
    let payload = unframe(KIND_NET_LOGITS, frame)?;
    let mut r = ByteReader::new(payload);
    let variant = r.str()?;
    let queue = Duration::from_micros(r.u64()?);
    let exec = Duration::from_micros(r.u64()?);
    let ct_logits = Ciphertext::read_payload(&mut r)?;
    r.finish()?;
    Ok(InferOutcome { variant, ct_logits, queue, exec })
}

/// Decision-mode response (DESIGN.md S20): the logits-frame payload
/// prefixed with the output-mode triple the plan evaluated, so the reply
/// is self-describing — a client can never misread an argmax indicator
/// ciphertext as raw class scores.
fn decision_frame(out: &InferOutcome, mode: OutputMode) -> Vec<u8> {
    frame_with(KIND_NET_DECISION, |w| {
        write_output_mode(w, mode);
        w.put_str(&out.variant);
        w.put_u64(out.queue.as_micros() as u64);
        w.put_u64(out.exec.as_micros() as u64);
        out.ct_logits.write_payload(w);
    })
}

/// Parse a `NET_DECISION` reply. Public for the client and the
/// hostile-frame fuzz suite: forged mode tags, non-finite cutoffs, and
/// truncated payloads all error typed — never panic.
pub fn parse_decision_frame(frame: &[u8]) -> Result<(OutputMode, InferOutcome)> {
    let payload = unframe(KIND_NET_DECISION, frame)?;
    let mut r = ByteReader::new(payload);
    let mode = read_output_mode(&mut r)?;
    let variant = r.str()?;
    let queue = Duration::from_micros(r.u64()?);
    let exec = Duration::from_micros(r.u64()?);
    let ct_logits = Ciphertext::read_payload(&mut r)?;
    r.finish()?;
    Ok((mode, InferOutcome { variant, ct_logits, queue, exec }))
}

// ---------------------------------------------------------------------------
// incremental frame reading
// ---------------------------------------------------------------------------

/// Why a socket read failed — drives the close-vs-reply policy. A clean
/// EOF *between* frames is a normal goodbye; everything else is a fault.
enum ReadFail {
    CleanEof,
    Timeout,
    Disconnected(String),
    /// Header bytes that are not a codec frame (wrong magic / version /
    /// reserved byte): frame sync is gone, the connection must close.
    Hostile(String),
    /// Length prefix over the kind's budget — rejected before allocating.
    TooLarge { kind: u8, len: u64, max: u64 },
}

fn read_full(r: &mut impl Read, buf: &mut [u8], at_boundary: bool) -> std::result::Result<(), ReadFail> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if at_boundary && got == 0 {
                    ReadFail::CleanEof
                } else {
                    ReadFail::Disconnected(format!(
                        "peer closed mid-frame ({got}/{} bytes)",
                        buf.len()
                    ))
                })
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err(ReadFail::Timeout)
            }
            Err(e) => return Err(ReadFail::Disconnected(e.to_string())),
        }
    }
    Ok(())
}

/// Read one frame incrementally: 16-byte header first, validate magic /
/// version / reserved / length-vs-budget, and only then allocate and read
/// the payload + checksum. Returns the *complete* frame bytes so callers
/// hand them to [`unframe`] for the checksum pass.
fn read_frame(
    r: &mut impl Read,
    max_for: &dyn Fn(u8) -> u64,
) -> std::result::Result<(u8, Vec<u8>), ReadFail> {
    let mut header = [0u8; HEADER_LEN];
    read_full(r, &mut header, true)?;
    if header[0..4] != MAGIC {
        return Err(ReadFail::Hostile("frame magic mismatch".into()));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(ReadFail::Hostile(format!("unsupported wire version {version}")));
    }
    if header[7] != 0 {
        return Err(ReadFail::Hostile("frame reserved byte damaged".into()));
    }
    let kind = header[6];
    // fixed-width destructure, no slice conversion: the socket read path
    // must hold zero unwraps reachable from hostile bytes (S21 audit)
    let len = u64::from_le_bytes([
        header[8], header[9], header[10], header[11], header[12], header[13], header[14],
        header[15],
    ]);
    let max = max_for(kind);
    if len > max {
        return Err(ReadFail::TooLarge { kind, len, max });
    }
    let mut frame = Vec::with_capacity(HEADER_LEN + len as usize + CHECKSUM_LEN);
    frame.extend_from_slice(&header);
    frame.resize(HEADER_LEN + len as usize + CHECKSUM_LEN, 0);
    read_full(r, &mut frame[HEADER_LEN..], false)?;
    Ok((kind, frame))
}

/// Client-side / test-harness frame reader with a uniform budget, mapping
/// read failures to errors with stable message tokens.
pub fn read_frame_budget(r: &mut impl Read, max: u64) -> Result<(u8, Vec<u8>)> {
    match read_frame(r, &|_| max) {
        Ok(x) => Ok(x),
        Err(ReadFail::CleanEof) => bail!("connection closed"),
        Err(ReadFail::Timeout) => bail!("read timed out"),
        Err(ReadFail::Disconnected(m)) => bail!("connection lost: {m}"),
        Err(ReadFail::Hostile(m)) => bail!("malformed frame: {m}"),
        Err(ReadFail::TooLarge { len, max, .. }) => {
            bail!("frame too large ({len} > budget {max})")
        }
    }
}

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

/// Byte-counting wrapper feeding the `net_bytes_in`/`net_bytes_out`
/// serving metrics.
struct MeteredStream {
    inner: TcpStream,
    metrics: Arc<Metrics>,
}

impl Read for MeteredStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.metrics.net_bytes_in.fetch_add(n as u64, Ordering::Relaxed);
        Ok(n)
    }
}

impl Write for MeteredStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.metrics.net_bytes_out.fetch_add(n as u64, Ordering::Relaxed);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Poison-immune lock: a handler that panicked while holding a counter
/// map must not wedge every other connection.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// RAII slot in a per-tenant counter map (connection or in-flight quota).
/// Dropping releases the slot even on panic or early return.
struct TenantSlot<'a> {
    map: &'a Mutex<HashMap<String, usize>>,
    tenant: String,
}

impl<'a> TenantSlot<'a> {
    /// `quota == 0` means unlimited.
    fn acquire(
        map: &'a Mutex<HashMap<String, usize>>,
        tenant: &str,
        quota: usize,
    ) -> Option<Self> {
        let mut m = lock(map);
        let n = m.entry(tenant.to_string()).or_insert(0);
        if quota > 0 && *n >= quota {
            if *n == 0 {
                m.remove(tenant);
            }
            return None;
        }
        *n += 1;
        Some(TenantSlot { map, tenant: tenant.to_string() })
    }
}

impl Drop for TenantSlot<'_> {
    fn drop(&mut self) {
        let mut m = lock(self.map);
        if let Some(n) = m.get_mut(&self.tenant) {
            *n -= 1;
            if *n == 0 {
                m.remove(&self.tenant);
            }
        }
    }
}

/// Gauge decrement on drop (panic-safe `net_conns_active` accounting).
struct GaugeGuard<'a>(&'a AtomicU64);

impl Drop for GaugeGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

struct Shared {
    backend: Arc<dyn NetBackend>,
    metrics: Arc<Metrics>,
    cfg: NetConfig,
    stop: AtomicBool,
    /// Per-tenant open connections (hello-stage admission).
    conns: Mutex<HashMap<String, usize>>,
    /// Per-tenant requests inside the backend (request-stage admission).
    inflight: Mutex<HashMap<String, usize>>,
    /// Per-tenant refresh rounds currently on the wire (round-stage
    /// admission; DESIGN.md S21).
    rounds_inflight: Mutex<HashMap<String, usize>>,
    /// Stream clones for forced shutdown of blocked handler threads.
    live: Mutex<HashMap<u64, TcpStream>>,
    handlers: Mutex<Vec<JoinHandle<()>>>,
    next_conn_id: AtomicU64,
    /// Refresh session-token sequence (scrambled through
    /// [`session_token`] per interactive request).
    next_token: AtomicU64,
}

/// Thread-per-connection TCP server. [`NetServer::bind`] returning is the
/// readiness signal (the listener is accepting); tests bind `127.0.0.1:0`
/// and read the real port from [`NetServer::local_addr`] — no sleeps.
pub struct NetServer {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    pub fn bind(
        addr: &str,
        backend: Arc<dyn NetBackend>,
        metrics: Arc<Metrics>,
        cfg: NetConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        let shared = Arc::new(Shared {
            backend,
            metrics,
            cfg,
            stop: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            inflight: Mutex::new(HashMap::new()),
            rounds_inflight: Mutex::new(HashMap::new()),
            live: Mutex::new(HashMap::new()),
            handlers: Mutex::new(Vec::new()),
            next_conn_id: AtomicU64::new(0),
            next_token: AtomicU64::new(0),
        });
        let accept = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(NetServer { local_addr, shared, accept: Some(accept) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Stop accepting, force open connections off their sockets, and join
    /// every thread. Safe to call with clients still connected.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept() with a throwaway connection
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for (_, s) in lock(&self.shared.live).drain() {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handlers: Vec<_> = lock(&self.shared.handlers).drain(..).collect();
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_and_join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _peer)) => s,
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            break; // the shutdown wake-up connection
        }
        let id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            lock(&shared.live).insert(id, clone);
        }
        let sh = shared.clone();
        let handle = std::thread::spawn(move || {
            // a panicking handler must not take the process (or the
            // accept loop) down with it — the connection just dies
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handle_conn(stream, &sh)
            }));
            if res.is_err() {
                sh.metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
            }
            lock(&sh.live).remove(&id);
        });
        let mut handlers = lock(&shared.handlers);
        handlers.retain(|h| !h.is_finished());
        handlers.push(handle);
    }
}

fn send_bytes(io: &mut MeteredStream, bytes: &[u8]) -> std::io::Result<()> {
    io.write_all(bytes)?;
    io.flush()
}

fn send_error(io: &mut MeteredStream, code: u32, message: &str) -> std::io::Result<()> {
    send_bytes(io, &error_frame(code, message))
}

/// Best-effort typed error for a read failure, where the protocol still
/// allows one. Timeouts and oversize claims get a frame (the socket is
/// still writable and sync is irrelevant — we close right after); a
/// vanished peer gets nothing.
fn fault_reply(io: &mut MeteredStream, fail: &ReadFail) {
    let (code, msg) = match fail {
        ReadFail::CleanEof | ReadFail::Disconnected(_) => return,
        ReadFail::Timeout => (ERR_TIMEOUT, "read timed out (slow or stalled client)".to_string()),
        ReadFail::Hostile(m) => (ERR_BAD_FRAME, m.clone()),
        ReadFail::TooLarge { kind, len, max } => (
            ERR_TOO_LARGE,
            format!("frame kind {kind} claims {len} payload bytes (budget {max})"),
        ),
    };
    let _ = send_error(io, code, &msg);
}

fn handle_conn(stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    if shared.cfg.read_timeout > Duration::ZERO {
        let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
    }
    if shared.cfg.write_timeout > Duration::ZERO {
        let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    }
    let metrics = shared.metrics.clone();
    metrics.net_conns_active.fetch_add(1, Ordering::Relaxed);
    let _active = GaugeGuard(&shared.metrics.net_conns_active);
    let mut io = MeteredStream { inner: stream, metrics: metrics.clone() };
    let max_for = |kind: u8| {
        if kind == KIND_NET_REGISTER {
            shared.cfg.max_key_frame_bytes
        } else {
            shared.cfg.max_frame_bytes
        }
    };

    // --- hello + connection admission -------------------------------------
    let (kind, frame) = match read_frame(&mut io, &max_for) {
        Ok(x) => x,
        Err(fail) => {
            metrics.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
            fault_reply(&mut io, &fail);
            return;
        }
    };
    if kind != KIND_NET_HELLO {
        metrics.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
        let _ = send_error(&mut io, ERR_PROTOCOL, "expected a hello frame first");
        return;
    }
    let (proto, tenant) = match parse_hello(&frame) {
        Ok(x) => x,
        Err(e) => {
            metrics.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
            let _ = send_error(&mut io, ERR_BAD_FRAME, &format!("hello rejected: {e:#}"));
            return;
        }
    };
    if proto != NET_PROTO {
        metrics.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
        let _ = send_error(
            &mut io,
            ERR_PROTOCOL,
            &format!("protocol revision {proto} not supported (server speaks {NET_PROTO})"),
        );
        return;
    }
    let _conn_slot =
        match TenantSlot::acquire(&shared.conns, &tenant, shared.cfg.max_conns_per_tenant) {
            Some(slot) => slot,
            None => {
                metrics.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
                let _ = send_error(
                    &mut io,
                    ERR_OVER_QUOTA,
                    &format!(
                        "tenant {tenant} is at its connection quota ({})",
                        shared.cfg.max_conns_per_tenant
                    ),
                );
                return;
            }
        };
    metrics.net_conns_accepted.fetch_add(1, Ordering::Relaxed);
    if send_bytes(&mut io, &ok_frame("lingcn-wire/1")).is_err() {
        return;
    }

    // --- command loop ------------------------------------------------------
    loop {
        let (kind, frame) = match read_frame(&mut io, &max_for) {
            Ok(x) => x,
            Err(ReadFail::CleanEof) => return,
            Err(fail) => {
                fault_reply(&mut io, &fail);
                return;
            }
        };
        match kind {
            KIND_NET_REGISTER => match parse_register(&frame) {
                Ok(key_set) => match shared.backend.register(&tenant, key_set) {
                    Ok(()) => {
                        if send_bytes(&mut io, &ok_frame("registered")).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
                        // content was well-framed but the HE stack refused
                        // it — the connection stays usable
                        if send_error(&mut io, ERR_REJECTED, &format!("{e:#}")).is_err() {
                            return;
                        }
                    }
                },
                Err(e) => {
                    // can't trust frame sync after a malformed key bundle
                    metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = send_error(
                        &mut io,
                        ERR_BAD_FRAME,
                        &format!("eval-key frame rejected: {e:#}"),
                    );
                    return;
                }
            },
            KIND_NET_INFER => {
                if !serve_infer(&mut io, shared, &tenant, &frame, &max_for) {
                    return;
                }
            }
            KIND_NET_STATUS => {
                // Observability probe (DESIGN.md S19): answered straight
                // off the metrics registers, plan-profile EWMAs, and the
                // backend's plan-cache view — no HE pipeline involvement,
                // so it works even while inference is in flight.
                if let Err(e) = parse_status_request(&frame) {
                    metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
                    let _ =
                        send_error(&mut io, ERR_BAD_FRAME, &format!("status rejected: {e:#}"));
                    return;
                }
                let mut json = format!(
                    "{{\"metrics\":{},\"profiles\":{}",
                    metrics.snapshot(),
                    crate::he_infer::profile::profiles_json()
                );
                let backend = shared.backend.status_json();
                if !backend.is_empty() {
                    json.push_str(",\"backend\":");
                    json.push_str(&backend);
                }
                json.push('}');
                let reply = frame_with(KIND_NET_STATUS, |w| w.put_str(&json));
                if send_bytes(&mut io, &reply).is_err() {
                    return;
                }
            }
            other => {
                let _ = send_error(
                    &mut io,
                    ERR_PROTOCOL,
                    &format!("unexpected frame kind {other} (want register, infer, or status)"),
                );
                return;
            }
        }
    }
}

/// Handle one `NET_INFER`: admission first, then stream the announced
/// ciphertext frames one at a time into the validator. Returns whether
/// the connection is still in sync (keep serving it).
fn serve_infer(
    io: &mut MeteredStream,
    shared: &Shared,
    tenant: &str,
    header_frame: &[u8],
    max_for: &dyn Fn(u8) -> u64,
) -> bool {
    let metrics = &shared.metrics;
    let hdr = match parse_infer_header(header_frame, shared.cfg.max_request_cts) {
        Ok(h) => h,
        Err(e) => {
            metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
            let _ = send_error(io, ERR_BAD_FRAME, &format!("infer header rejected: {e:#}"));
            return false;
        }
    };

    // admission before ingesting a single ciphertext byte
    let mut reject: Option<(u32, String)> = None;
    if !shared.backend.is_registered(tenant) {
        reject = Some((
            ERR_UNKNOWN_TENANT,
            format!("tenant {tenant} has no registered eval keys (send a register frame first)"),
        ));
    }
    if reject.is_none() && hdr.mode != shared.backend.output_mode() {
        // a mode the serving plans were not compiled for is refused here,
        // typed, with the announced frames drained below — never silently
        // answered with a different output shape
        reject = Some((
            ERR_MODE_MISMATCH,
            format!(
                "request asked for output mode {} but this server's plans are \
                 compiled for {}",
                hdr.mode,
                shared.backend.output_mode()
            ),
        ));
    }
    let slot = if reject.is_none() {
        match TenantSlot::acquire(&shared.inflight, tenant, shared.cfg.max_inflight_per_tenant) {
            Some(slot) => Some(slot),
            None => {
                reject = Some((
                    ERR_OVER_QUOTA,
                    format!(
                        "tenant {tenant} is at its in-flight request quota ({})",
                        shared.cfg.max_inflight_per_tenant
                    ),
                ));
                None
            }
        }
    } else {
        None
    };
    if let Some((code, msg)) = reject {
        metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
        // drain the announced frames (bounded by the header we already
        // validated) so the client — likely still mid-write — reliably
        // receives the typed error and the connection stays in sync
        for _ in 0..hdr.ct_count {
            match read_frame(io, max_for) {
                Ok((KIND_CIPHERTEXT, _)) => {}
                Ok(_) | Err(_) => return false,
            }
        }
        return send_error(io, code, &msg).is_ok();
    }

    // streamed upload: frame-at-a-time into the validator
    let mut cts = Vec::with_capacity(hdr.ct_count);
    for i in 0..hdr.ct_count {
        let (kind, frame) = match read_frame(io, max_for) {
            Ok(x) => x,
            Err(fail) => {
                fault_reply(io, &fail);
                return false;
            }
        };
        if kind != KIND_CIPHERTEXT {
            let _ = send_error(
                io,
                ERR_PROTOCOL,
                &format!("expected ciphertext frame {i}/{}, got kind {kind}", hdr.ct_count),
            );
            return false;
        }
        match Ciphertext::from_bytes(&frame) {
            Ok(ct) => cts.push(ct),
            Err(e) => {
                metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
                let _ = send_error(
                    io,
                    ERR_BAD_FRAME,
                    &format!("ciphertext frame {i} rejected: {e:#}"),
                );
                return false;
            }
        }
    }

    // non-interactive requests take the straight-line path: one backend
    // call, one reply frame
    if hdr.max_rounds == 0 {
        let outcome =
            shared.backend.infer(tenant, hdr.variant, cts, hdr.params_hash, hdr.batch, hdr.mode);
        drop(slot); // release the in-flight quota before writing the reply
        return finish_infer(io, metrics, hdr.mode, outcome);
    }

    // Interactive path (DESIGN.md S21): the backend call moves to a worker
    // thread holding a NetRefreshBridge, while this handler thread stays
    // on the socket servicing each refresh round — REFRESH_REQ out,
    // REFRESH_RESP in — until the bridge drops, which is the completion
    // signal either way (success or a failed round unwinding the
    // executor). The protocol is stateful across frames from here on:
    // every round is correlated by the session token + round index.
    let max_rounds = if shared.cfg.max_refresh_rounds == 0 {
        hdr.max_rounds
    } else {
        hdr.max_rounds.min(shared.cfg.max_refresh_rounds)
    };
    let token = session_token(shared.next_token.fetch_add(1, Ordering::Relaxed));
    let (tx, rx) = mpsc::channel();
    let src: Arc<dyn RefreshSource> =
        Arc::new(NetRefreshBridge { tx: Mutex::new(tx), max_rounds });
    let worker = {
        let backend = shared.backend.clone();
        let tenant = tenant.to_string();
        let variant = hdr.variant.clone();
        let (params_hash, batch, mode) = (hdr.params_hash, hdr.batch, hdr.mode);
        std::thread::spawn(move || {
            backend.infer_rounds(&tenant, variant, cts, params_hash, batch, mode, Some(src))
        })
    };
    let mut in_sync = true;
    let mut served = 0u64;
    let mut waited_us = 0u64;
    while let Ok(req) = rx.recv() {
        // per-tenant round quota: an over-quota round fails this
        // inference typed (the executor unwinds) without desyncing the
        // socket — no REFRESH_REQ was sent for it
        let round_slot = TenantSlot::acquire(
            &shared.rounds_inflight,
            tenant,
            shared.cfg.max_rounds_inflight_per_tenant,
        );
        if round_slot.is_none() {
            metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
            let _ = req.reply.send(Err(anyhow!(
                "tenant {tenant} is at its in-flight refresh-round quota ({})",
                shared.cfg.max_rounds_inflight_per_tenant
            )));
            continue;
        }
        let t0 = std::time::Instant::now();
        if send_bytes(io, &refresh_req_frame(token, req.round as u32, &req.masked)).is_err() {
            let _ = req
                .reply
                .send(Err(anyhow!("refresh round {}: writing to the client failed", req.round)));
            in_sync = false;
            break;
        }
        let resp = match read_frame(io, max_for) {
            Ok((KIND_NET_REFRESH_RESP, frame)) => frame,
            Ok((kind, _)) => {
                let _ = send_error(
                    io,
                    ERR_PROTOCOL,
                    &format!("expected a refresh response frame, got kind {kind}"),
                );
                let _ = req.reply.send(Err(anyhow!(
                    "refresh round {}: client answered frame kind {kind}",
                    req.round
                )));
                in_sync = false;
                break;
            }
            Err(fail) => {
                fault_reply(io, &fail);
                let _ = req.reply.send(Err(anyhow!(
                    "refresh round {}: client connection failed mid-round",
                    req.round
                )));
                in_sync = false;
                break;
            }
        };
        match parse_refresh_resp(&resp, req.masked.len()) {
            Ok((tok, rnd, fresh))
                if tok == token
                    && rnd as usize == req.round
                    && fresh.len() == req.masked.len() =>
            {
                served += 1;
                waited_us += t0.elapsed().as_micros() as u64;
                let _ = req.reply.send(Ok(fresh));
            }
            Ok((tok, rnd, _)) => {
                // stale or replayed round correlation: typed refusal, and
                // frame sync is unknowable — close after the worker settles
                metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
                let _ = send_error(
                    io,
                    ERR_PROTOCOL,
                    &format!(
                        "refresh response correlation mismatch: got token {tok:#018x} \
                         round {rnd}, want token {token:#018x} round {}",
                        req.round
                    ),
                );
                let _ = req.reply.send(Err(anyhow!(
                    "refresh round {}: stale or replayed response (token/round mismatch)",
                    req.round
                )));
                in_sync = false;
                break;
            }
            Err(e) => {
                metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
                let _ =
                    send_error(io, ERR_BAD_FRAME, &format!("refresh response rejected: {e:#}"));
                let _ = req.reply.send(Err(anyhow!(
                    "refresh round {}: malformed response",
                    req.round
                )));
                in_sync = false;
                break;
            }
        }
    }
    // dropping the receiver here fails any later bridge round fast —
    // the executor unwinds instead of hanging on a dead socket
    drop(rx);
    metrics.refresh_rounds.fetch_add(served, Ordering::Relaxed);
    metrics.refresh_wait_us.fetch_add(waited_us, Ordering::Relaxed);
    let outcome =
        worker.join().unwrap_or_else(|_| Err(anyhow!("inference worker thread panicked")));
    drop(slot); // release the in-flight quota before writing the reply
    if !in_sync {
        // the typed error (where one was possible) already went out;
        // frame sync is gone, so the connection must close — the server
        // itself keeps serving every other connection
        if outcome.is_err() {
            metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
        }
        return false;
    }
    finish_infer(io, metrics, hdr.mode, outcome)
}

/// Terminal step of both `serve_infer` paths: one logits/decision reply
/// on success, one typed `rejected` on failure.
fn finish_infer(
    io: &mut MeteredStream,
    metrics: &Arc<Metrics>,
    mode: OutputMode,
    outcome: Result<InferOutcome>,
) -> bool {
    match outcome {
        Ok(out) => {
            let reply = if matches!(mode, OutputMode::Logits) {
                logits_frame(&out)
            } else {
                decision_frame(&out, mode)
            };
            send_bytes(io, &reply).is_ok()
        }
        Err(e) => {
            metrics.net_requests_rejected.fetch_add(1, Ordering::Relaxed);
            send_error(io, ERR_REJECTED, &format!("{e:#}")).is_ok()
        }
    }
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// Blocking client for the TCP tier. Holds no key material — callers pair
/// it with [`ClientKeys`](crate::wire::ClientKeys) for keygen / encrypt /
/// decrypt, so the privacy boundary is unchanged: only eval keys and
/// ciphertexts ever reach this type.
pub struct Client {
    io: TcpStream,
    max_frame: u64,
    /// Wire bytes written / read by this client (for the CLI's transfer
    /// report and the loopback bench).
    pub bytes_out: u64,
    pub bytes_in: u64,
}

impl Client {
    pub fn connect(addr: &str, tenant: &str) -> Result<Client> {
        Self::connect_with(addr, tenant, Duration::from_secs(30))
    }

    /// Connect, send the hello, and wait for the server's admission
    /// verdict. `timeout` bounds every subsequent read and write;
    /// `Duration::ZERO` means unbounded.
    pub fn connect_with(addr: &str, tenant: &str, timeout: Duration) -> Result<Client> {
        validate_tenant(tenant)?;
        let io = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = io.set_nodelay(true);
        if timeout > Duration::ZERO {
            let _ = io.set_read_timeout(Some(timeout));
            let _ = io.set_write_timeout(Some(timeout));
        }
        let mut client =
            Client { io, max_frame: NetConfig::default().max_frame_bytes, bytes_out: 0, bytes_in: 0 };
        client.send(&hello_frame(tenant))?;
        let frame = client.expect_reply(KIND_NET_OK)?;
        parse_ok_frame(&frame)?;
        Ok(client)
    }

    fn send(&mut self, bytes: &[u8]) -> Result<()> {
        self.io.write_all(bytes).context("writing to server")?;
        self.io.flush().context("flushing to server")?;
        self.bytes_out += bytes.len() as u64;
        Ok(())
    }

    fn expect_reply(&mut self, want_kind: u8) -> Result<Vec<u8>> {
        let (kind, frame) = read_frame_budget(&mut self.io, self.max_frame)?;
        self.bytes_in += frame.len() as u64;
        if kind == KIND_NET_ERROR {
            let (code, message) = parse_error_frame(&frame)?;
            bail!("server error [{}]: {message}", err_name(code));
        }
        ensure!(kind == want_kind, "unexpected reply frame kind {kind} (want {want_kind})");
        Ok(frame)
    }

    /// Register this tenant's evaluation keys with the server.
    pub fn register(&mut self, key_set: &EvalKeySet) -> Result<()> {
        let frame = frame_with(KIND_NET_REGISTER, |w| key_set.write_payload(w));
        self.send(&frame)?;
        let reply = self.expect_reply(KIND_NET_OK)?;
        parse_ok_frame(&reply)?;
        Ok(())
    }

    /// Upload a request bundle (streamed: header frame, then one codec
    /// frame per ciphertext — byte-identical to `Ciphertext::to_bytes`)
    /// and block for the encrypted result. The bundle's `mode` selects
    /// the expected reply: raw logits arrive as a `NET_LOGITS` frame,
    /// decision modes as a `NET_DECISION` frame whose echoed mode must
    /// match the request — a server answering a different mode is a typed
    /// error, not a silently misread ciphertext.
    pub fn infer(&mut self, variant: Option<&str>, bundle: &CtBundle) -> Result<InferOutcome> {
        self.send(&infer_header_frame(
            variant,
            Some(bundle.params_hash),
            bundle.batch,
            bundle.mode,
            bundle.cts.len(),
        ))?;
        for ct in &bundle.cts {
            self.send(&ct.to_bytes())?;
        }
        if matches!(bundle.mode, OutputMode::Logits) {
            let reply = self.expect_reply(KIND_NET_LOGITS)?;
            parse_logits_frame(&reply)
        } else {
            let reply = self.expect_reply(KIND_NET_DECISION)?;
            let (mode, out) = parse_decision_frame(&reply)?;
            ensure!(
                mode == bundle.mode,
                "server answered output mode {mode}, request asked for {}",
                bundle.mode
            );
            Ok(out)
        }
    }

    /// [`Client::infer`] with an interactive refresh budget
    /// (DESIGN.md S21): announce up to `max_rounds` refresh rounds and
    /// service each one the server asks for — decrypt the masked
    /// level-0 intermediates with `keys` and re-encrypt them at the top
    /// of the chain — before the final logits/decision frame arrives.
    /// Returns the outcome plus the number of rounds actually served.
    /// The server's rounds must arrive in order under one session token;
    /// anything else is a typed error, and a round beyond the announced
    /// budget is refused client-side too.
    pub fn infer_with_refresh(
        &mut self,
        variant: Option<&str>,
        bundle: &CtBundle,
        keys: &ClientKeys,
        max_rounds: u32,
    ) -> Result<(InferOutcome, usize)> {
        self.send(&infer_header_frame_rounds(
            variant,
            Some(bundle.params_hash),
            bundle.batch,
            bundle.mode,
            bundle.cts.len(),
            max_rounds,
        ))?;
        for ct in &bundle.cts {
            self.send(&ct.to_bytes())?;
        }
        let mut rounds = 0usize;
        let mut token: Option<u64> = None;
        loop {
            let (kind, frame) = read_frame_budget(&mut self.io, self.max_frame)?;
            self.bytes_in += frame.len() as u64;
            match kind {
                KIND_NET_ERROR => {
                    let (code, message) = parse_error_frame(&frame)?;
                    bail!("server error [{}]: {message}", err_name(code));
                }
                KIND_NET_REFRESH_REQ => {
                    ensure!(
                        rounds < max_rounds as usize,
                        "server asked for refresh round {rounds} beyond the announced \
                         budget of {max_rounds}"
                    );
                    let (tok, rnd, masked) = parse_refresh_req(&frame, MAX_BATCH)?;
                    match token {
                        None => token = Some(tok),
                        Some(t) => ensure!(
                            t == tok,
                            "server switched session token mid-inference \
                             ({t:#018x} -> {tok:#018x})"
                        ),
                    }
                    ensure!(
                        rnd as usize == rounds,
                        "server sent refresh round {rnd}, expected {rounds}"
                    );
                    let fresh: Vec<Ciphertext> =
                        masked.iter().map(|ct| keys.refresh_ct(ct)).collect::<Result<_>>()?;
                    self.send(&refresh_resp_frame(tok, rnd, &fresh))?;
                    rounds += 1;
                }
                KIND_NET_LOGITS => {
                    ensure!(
                        matches!(bundle.mode, OutputMode::Logits),
                        "server answered raw logits, request asked for {}",
                        bundle.mode
                    );
                    return Ok((parse_logits_frame(&frame)?, rounds));
                }
                KIND_NET_DECISION => {
                    let (mode, out) = parse_decision_frame(&frame)?;
                    ensure!(
                        mode == bundle.mode,
                        "server answered output mode {mode}, request asked for {}",
                        bundle.mode
                    );
                    return Ok((out, rounds));
                }
                other => bail!("unexpected frame kind {other} during interactive inference"),
            }
        }
    }

    /// Fetch the server's live status snapshot — metrics registers,
    /// per-plan profile EWMAs, and (on the production backend) the plan
    /// cache — as one JSON document.
    pub fn status(&mut self) -> Result<String> {
        self.send(&status_frame())?;
        let reply = self.expect_reply(KIND_NET_STATUS)?;
        parse_status_frame(&reply)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn test_control_frames_roundtrip() {
        let (proto, tenant) = parse_hello(&hello_frame("alice")).unwrap();
        assert_eq!(proto, NET_PROTO);
        assert_eq!(tenant, "alice");
        assert_eq!(parse_ok_frame(&ok_frame("hi")).unwrap(), "hi");
        let (code, msg) = parse_error_frame(&error_frame(ERR_OVER_QUOTA, "full")).unwrap();
        assert_eq!(code, ERR_OVER_QUOTA);
        assert_eq!(msg, "full");
    }

    #[test]
    fn test_status_frames_roundtrip() {
        parse_status_request(&status_frame()).unwrap();
        // a stray payload on the request is a typed protocol fault
        let bad = frame_with(KIND_NET_STATUS, |w| w.put_u8(1));
        assert!(parse_status_request(&bad).is_err());
        let json = "{\"metrics\":{},\"profiles\":[]}";
        let reply = frame_with(KIND_NET_STATUS, |w| w.put_str(json));
        assert_eq!(parse_status_frame(&reply).unwrap(), json);
    }

    #[test]
    fn test_tenant_validation() {
        assert!(validate_tenant("alice").is_ok());
        assert!(validate_tenant("").is_err());
        assert!(validate_tenant(&"x".repeat(129)).is_err());
        // the coordinator's composite queue-key separator must be illegal
        assert!(validate_tenant("a\u{1}b").is_err());
        assert!(validate_tenant("a\nb").is_err());
    }

    #[test]
    fn test_infer_header_roundtrip_and_bounds() {
        let f = infer_header_frame(Some("lingcn-nl2"), Some(7), 2, OutputMode::Argmax, 3);
        let h = parse_infer_header(&f, 16).unwrap();
        assert_eq!(h.variant.as_deref(), Some("lingcn-nl2"));
        assert_eq!(h.params_hash, Some(7));
        assert_eq!(h.batch, 2);
        assert_eq!(h.mode, OutputMode::Argmax);
        assert_eq!(h.ct_count, 3);
        // empty variant string travels as None; absent hash as None
        let lo = OutputMode::Logits;
        let h = parse_infer_header(&infer_header_frame(None, None, 1, lo, 1), 16).unwrap();
        assert!(h.variant.is_none() && h.params_hash.is_none());
        assert_eq!(h.mode, OutputMode::Logits);
        // count over the server budget is rejected at the header
        assert!(parse_infer_header(&infer_header_frame(None, None, 1, lo, 17), 16).is_err());
        assert!(parse_infer_header(&infer_header_frame(None, None, 0, lo, 1), 16).is_err());
        assert!(parse_infer_header(&infer_header_frame(None, None, 1, lo, 0), 16).is_err());
        // a forged mode tag in the header errors typed, never panics
        let forged = frame_with(KIND_NET_INFER, |w| {
            w.put_str("");
            w.put_u8(0);
            w.put_u64(0);
            w.put_u64(1);
            w.put_u8(42); // no such mode tag
            w.put_u32(0);
            w.put_u64(0);
            w.put_u32(1);
        });
        let err = parse_infer_header(&forged, 16).unwrap_err().to_string();
        assert!(err.contains("unknown output-mode tag 42"), "{err}");
    }

    #[test]
    fn test_infer_header_refresh_budget_is_tolerant_trailing_field() {
        // the plain header announces no budget
        let f = infer_header_frame(Some("v"), None, 1, OutputMode::Logits, 2);
        assert_eq!(parse_infer_header(&f, 16).unwrap().max_rounds, 0);
        // the rounds variant carries it
        let f = infer_header_frame_rounds(Some("v"), None, 1, OutputMode::Logits, 2, 5);
        let h = parse_infer_header(&f, 16).unwrap();
        assert_eq!(h.max_rounds, 5);
        assert_eq!(h.ct_count, 2);
        // a pre-S21 header that ends at the count still parses (budget 0)
        let legacy = frame_with(KIND_NET_INFER, |w| {
            w.put_str("v");
            w.put_u8(0);
            w.put_u64(0);
            w.put_u64(1);
            w.put_u8(0); // logits tag
            w.put_u32(0);
            w.put_u64(0);
            w.put_u32(1);
        });
        assert_eq!(parse_infer_header(&legacy, 16).unwrap().max_rounds, 0);
        // bytes after the budget are still a typed fault
        let trailing = frame_with(KIND_NET_INFER, |w| {
            w.put_str("v");
            w.put_u8(0);
            w.put_u64(0);
            w.put_u64(1);
            w.put_u8(0);
            w.put_u32(0);
            w.put_u64(0);
            w.put_u32(1);
            w.put_u32(3);
            w.put_u8(0xAB);
        });
        assert!(parse_infer_header(&trailing, 16).is_err());
    }

    #[test]
    fn test_refresh_frames_reject_forged_payloads_typed() {
        // count outside 1..=max is refused before any ciphertext parse
        let empty = frame_with(KIND_NET_REFRESH_RESP, |w| {
            w.put_u64(7);
            w.put_u32(0);
            w.put_u32(0);
        });
        let err = parse_refresh_resp(&empty, 8).unwrap_err().to_string();
        assert!(err.contains("outside 1..=8"), "{err}");
        let over = frame_with(KIND_NET_REFRESH_RESP, |w| {
            w.put_u64(7);
            w.put_u32(0);
            w.put_u32(9);
        });
        assert!(parse_refresh_resp(&over, 8).is_err());
        // garbage where a ciphertext should be is a decode error, never a
        // panic — the forged-REFRESH_RESP contract of the handler thread
        let garbage = frame_with(KIND_NET_REFRESH_RESP, |w| {
            w.put_u64(7);
            w.put_u32(0);
            w.put_u32(1);
            w.put_u8(0xAB);
        });
        assert!(parse_refresh_resp(&garbage, 8).is_err());
        // a req frame is not a resp frame: kind is part of the contract
        let req_shaped = frame_with(KIND_NET_REFRESH_REQ, |w| {
            w.put_u64(7);
            w.put_u32(0);
            w.put_u32(1);
            w.put_u8(0xAB);
        });
        assert!(parse_refresh_resp(&req_shaped, 8).is_err());
    }

    #[test]
    fn test_session_tokens_differ_per_request() {
        let a = session_token(0);
        let b = session_token(1);
        assert_ne!(a, b);
        assert_ne!(session_token(2), b);
    }

    #[test]
    fn test_net_refresh_bridge_budget_and_disconnect_are_typed() {
        let (tx, rx) = mpsc::channel();
        let bridge = NetRefreshBridge { tx: Mutex::new(tx), max_rounds: 1 };
        // a round past the budget is refused before touching the channel
        let err = bridge.refresh(&[], 1).unwrap_err().to_string();
        assert!(err.contains("exceeds the session budget of 1 round(s)"), "{err}");
        // a dropped handler receiver fails the round fast, typed
        drop(rx);
        let err = bridge.refresh(&[], 0).unwrap_err().to_string();
        assert!(err.contains("connection handler is gone"), "{err}");
    }

    #[test]
    fn test_err_name_tokens_are_stable() {
        for (code, name) in [
            (ERR_BAD_FRAME, "bad-frame"),
            (ERR_TOO_LARGE, "too-large"),
            (ERR_PROTOCOL, "protocol"),
            (ERR_UNKNOWN_TENANT, "unknown-tenant"),
            (ERR_OVER_QUOTA, "over-quota"),
            (ERR_REJECTED, "rejected"),
            (ERR_TIMEOUT, "timeout"),
            (ERR_INTERNAL, "internal"),
            (ERR_MODE_MISMATCH, "mode-mismatch"),
        ] {
            assert_eq!(err_name(code), name);
        }
        assert_eq!(err_name(999), "unknown");
    }

    #[test]
    fn test_read_frame_happy_and_clean_eof() {
        let f = ok_frame("ping");
        let mut r = Cursor::new(f.clone());
        let (kind, got) = read_frame(&mut r, &|_| 1 << 20).unwrap();
        assert_eq!(kind, KIND_NET_OK);
        assert_eq!(got, f);
        // next read: clean EOF at a frame boundary
        assert!(matches!(read_frame(&mut r, &|_| 1 << 20), Err(ReadFail::CleanEof)));
    }

    #[test]
    fn test_read_frame_truncation_is_disconnect_not_clean() {
        let f = ok_frame("ping");
        for cut in [1, HEADER_LEN - 1, HEADER_LEN + 1, f.len() - 1] {
            let mut r = Cursor::new(f[..cut].to_vec());
            assert!(
                matches!(read_frame(&mut r, &|_| 1 << 20), Err(ReadFail::Disconnected(_))),
                "cut {cut}"
            );
        }
    }

    #[test]
    fn test_read_frame_hostile_header_rejected() {
        let mut bad_magic = ok_frame("x");
        bad_magic[0] ^= 0xFF;
        let mut r = Cursor::new(bad_magic);
        assert!(matches!(read_frame(&mut r, &|_| 1 << 20), Err(ReadFail::Hostile(_))));
        let mut bad_reserved = ok_frame("x");
        bad_reserved[7] = 9;
        let mut r = Cursor::new(bad_reserved);
        assert!(matches!(read_frame(&mut r, &|_| 1 << 20), Err(ReadFail::Hostile(_))));
    }

    #[test]
    fn test_read_frame_hostile_length_rejected_before_allocation() {
        // a header claiming u64::MAX payload bytes must fail from the
        // header alone (no allocation, no payload read)
        let mut header = Vec::new();
        header.extend_from_slice(&MAGIC);
        header.extend_from_slice(&VERSION.to_le_bytes());
        header.push(KIND_NET_INFER);
        header.push(0);
        header.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut r = Cursor::new(header);
        match read_frame(&mut r, &|_| 1 << 20) {
            Err(ReadFail::TooLarge { len, max, .. }) => {
                assert_eq!(len, u64::MAX);
                assert_eq!(max, 1 << 20);
            }
            _ => panic!("oversize claim must be TooLarge"),
        }
    }

    #[test]
    fn test_read_frame_per_kind_budget() {
        // register frames get the key budget, everything else the
        // ciphertext budget
        let big = ok_frame(&"y".repeat(100));
        let mut r = Cursor::new(big.clone());
        let budget = |kind: u8| if kind == KIND_NET_REGISTER { 1 << 20 } else { 10 };
        assert!(matches!(read_frame(&mut r, &budget), Err(ReadFail::TooLarge { .. })));
    }

    #[test]
    fn test_tenant_slot_quota_and_release() {
        let map = Mutex::new(HashMap::new());
        let a = TenantSlot::acquire(&map, "t", 2).expect("first slot");
        let _b = TenantSlot::acquire(&map, "t", 2).expect("second slot");
        assert!(TenantSlot::acquire(&map, "t", 2).is_none(), "third must hit quota");
        // another tenant is unaffected
        assert!(TenantSlot::acquire(&map, "u", 2).is_some());
        drop(a);
        assert!(TenantSlot::acquire(&map, "t", 2).is_some(), "drop frees the slot");
        // quota 0 = unlimited
        for _ in 0..10 {
            std::mem::forget(TenantSlot::acquire(&map, "v", 0).unwrap());
        }
    }

    #[test]
    fn test_logits_frame_needs_real_ct() {
        // a logits frame with garbage where the ciphertext should be is a
        // decode error, not a panic
        let f = frame_with(KIND_NET_LOGITS, |w| {
            w.put_str("v");
            w.put_u64(1);
            w.put_u64(2);
            w.put_u8(0xAB);
        });
        assert!(parse_logits_frame(&f).is_err());
    }

    #[test]
    fn test_decision_frame_rejects_forged_and_truncated_payloads() {
        // forged mode tag ahead of an otherwise plausible payload
        let forged_tag = frame_with(KIND_NET_DECISION, |w| {
            w.put_u8(9);
            w.put_u32(0);
            w.put_u64(0);
            w.put_str("v");
            w.put_u64(1);
            w.put_u64(2);
        });
        let err = parse_decision_frame(&forged_tag).unwrap_err().to_string();
        assert!(err.contains("unknown output-mode tag 9"), "{err}");
        // a NaN threshold cutoff is refused before the ciphertext parse
        let nan_cutoff = frame_with(KIND_NET_DECISION, |w| {
            w.put_u8(3);
            w.put_u32(1);
            w.put_u64(f64::NAN.to_bits());
            w.put_str("v");
        });
        assert!(parse_decision_frame(&nan_cutoff).is_err());
        // garbage where the ciphertext should be is a decode error
        let garbage_ct = frame_with(KIND_NET_DECISION, |w| {
            w.put_u8(1);
            w.put_u32(0);
            w.put_u64(0);
            w.put_str("v");
            w.put_u64(1);
            w.put_u64(2);
            w.put_u8(0xAB);
        });
        assert!(parse_decision_frame(&garbage_ct).is_err());
    }
}
