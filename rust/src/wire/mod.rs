//! The client/server **privacy boundary** (DESIGN.md S15).
//!
//! LinGCN's deployment model is that the cloud never sees client data:
//! keys are generated on the client, only the *evaluation* half (public
//! parameters, relinearization key, Galois keys) plus ciphertexts cross
//! the wire, and the reply is the ciphertext of the logits. This module
//! makes that boundary real — and type-checked:
//!
//! * [`codec`] — the versioned, length-prefixed, checksummed binary frame
//!   every wire object travels in; tampering and truncation are rejected,
//!   never panicked on.
//! * [`format`] — `to_bytes`/`from_bytes` ([`WireSerialize`]) for
//!   [`CkksParams`](crate::ckks::CkksParams),
//!   [`PublicKey`](crate::ckks::PublicKey),
//!   [`KeySwitchKey`](crate::ckks::KeySwitchKey),
//!   [`Ciphertext`](crate::ckks::Ciphertext), the [`EvalKeySet`] bundle a
//!   client registers, and the [`CtBundle`] a request ships.
//! * [`client`] — [`ClientKeys`]: seeded keygen, clip encryption, logits
//!   decryption. The only serializable holder of a secret key; its file
//!   format is local persistence, not a wire record.
//! * [`server`] — [`WireExecutor`]: the multi-tenant serving tier. Builds
//!   only [`EvalEngine`](crate::ckks::EvalEngine)s from registered key
//!   sets, so the serving path contains no `SecretKey` *by type*, and its
//!   plaintext `infer` entry point is a hard error.
//! * [`net`] — the TCP tier (DESIGN.md S18): [`NetServer`] speaks codec
//!   frames over sockets with streamed ciphertext upload, per-connection
//!   timeouts and per-tenant admission; [`net::Client`] is the matching
//!   blocking client (`lingcn infer-remote`).
//!
//! The full shell roundtrip (`lingcn keygen` → `encrypt` →
//! `serve --tier he-wire` → `decrypt-logits`) and the bit-identity of the
//! split path against the in-process `PrivateInferenceSession` are
//! covered by `rust/tests/wire_roundtrip.rs`.

pub mod client;
pub mod codec;
pub mod format;
pub mod net;
pub mod server;

pub use client::{keygen, keygen_with_state, ClientKeys, VariantSpec};
pub use format::{params_hash, CtBundle, EvalKeySet, WireSerialize};
pub use net::{CoordinatorBackend, InferOutcome, NetBackend, NetConfig, NetServer};
pub use server::{TenantKeys, WireExecutor, WireSession};
