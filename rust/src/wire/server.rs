//! The **server half** of the privacy boundary: a multi-tenant encrypted
//! executor that holds models, compiled plans, and each tenant's
//! registered [`EvalKeySet`] — and, by construction, no secret key. The
//! only engine type this module ever builds is [`EvalEngine`]
//! (`EvalKeySet::build_engine`), so the serving path cannot decrypt or
//! encrypt: requests arrive as ciphertext bundles and leave as the
//! ciphertext of the logits.

use super::format::EvalKeySet;
use crate::ckks::{Ciphertext, EvalEngine};
use crate::coordinator::{InferenceExecutor, KeyRegistry, Metrics};
use crate::he_infer::exec::{cached_slot_capacity, plan_for, record_opt_metrics, PlanKey};
use crate::he_infer::{
    sgn, session_geometry, HePlan, OutputMode, PlanChain, PlanOptions, PreparedPlan,
    RefreshSource, SgnPreset,
};
use crate::stgcn::StgcnModel;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// One tenant's registered key material plus the per-variant serving
/// state derived from it. The key-free engine is built — and the bundle
/// fully validated — **at registration** (`EvalKeySet::build_engine`),
/// so a malformed bundle fails `register`, not the tenant's first
/// request; all of the tenant's variant sessions share the one engine.
/// Evicting the tenant from the registry drops everything — keys,
/// engine, pre-encoded masks — in one `Arc` release.
pub struct TenantKeys {
    pub key_set: EvalKeySet,
    pub engine: EvalEngine,
    /// Serving sessions keyed by (variant, slot-batch size): batched
    /// bundles execute batch-compiled plans whose masks differ per size.
    sessions: Mutex<HashMap<(String, usize), Arc<WireSession>>>,
}

impl TenantKeys {
    pub fn new(key_set: EvalKeySet) -> Result<Self> {
        let engine = key_set.build_engine()?;
        Ok(TenantKeys {
            key_set,
            engine,
            sessions: Mutex::new(HashMap::new()),
        })
    }
}

/// A (tenant, variant) serving session: the compiled plan
/// (`prepared.plan`) with its masks pre-encoded against the tenant's
/// engine.
pub struct WireSession {
    pub prepared: PreparedPlan,
}

/// The wire-tier executor behind the coordinator: per-tenant key lookup
/// through the [`KeyRegistry`], cross-tenant plan sharing through the
/// same [`PlanKey`] cache as the trusted tier, and plan execution over
/// the wavefront pool. Implements [`InferenceExecutor`] with the
/// plaintext entry point **rejected** — this tier cannot see clips.
pub struct WireExecutor {
    pub threads: usize,
    opts: PlanOptions,
    models: HashMap<String, StgcnModel>,
    pub registry: Arc<KeyRegistry<TenantKeys>>,
    plans: Mutex<HashMap<PlanKey, Arc<HePlan>>>,
    /// Cached per-variant block-copy counts (geometry-only, no keys) —
    /// the occupancy denominator the coordinator's slot metrics use.
    capacities: Mutex<HashMap<String, usize>>,
    metrics: Option<Arc<Metrics>>,
    /// Randomness for the additive refresh masks (DESIGN.md S21). Seeded
    /// from the wall clock at construction so a restarted server never
    /// replays a mask sequence; every interactive request advances the
    /// shared state under the lock.
    mask_rng: Mutex<crate::util::Rng>,
}

impl WireExecutor {
    pub fn new(
        models: HashMap<String, StgcnModel>,
        threads: usize,
        registry: Arc<KeyRegistry<TenantKeys>>,
    ) -> Self {
        let clock_seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x6d61_736b_5f72_6e67);
        WireExecutor {
            threads: threads.max(1),
            opts: PlanOptions::default(),
            models,
            registry,
            plans: Mutex::new(HashMap::new()),
            capacities: Mutex::new(HashMap::new()),
            metrics: None,
            mask_rng: Mutex::new(crate::util::Rng::seed_from_u64(clock_seed)),
        }
    }

    /// Mirror plan-cache hits/misses into the coordinator metrics (call
    /// before handing the executor to `Coordinator::start_with_metrics`).
    pub fn set_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = Some(metrics);
    }

    /// Toggle the HePlan optimizer (DESIGN.md S17). Rotation-key
    /// requirements are identical either way, so existing tenant keys
    /// keep working; the flag only selects which plan family serves.
    pub fn set_optimize(&mut self, optimize: bool) {
        self.opts.optimize = optimize;
    }

    /// Select the decision circuit the serving plans are compiled with
    /// (DESIGN.md S20). Like [`WireExecutor::set_optimize`], call before
    /// serving traffic. Unlike the optimizer flag, this **does** change
    /// `required_rotations` and the chain depth, so tenants must keygen
    /// against the same mode — requests asking for any other mode are
    /// rejected at ingress, never silently answered with a different
    /// output shape.
    pub fn set_output_mode(&mut self, mode: OutputMode, preset: SgnPreset, bound: f64) {
        self.opts.output_mode = mode;
        self.opts.sgn_preset = preset;
        self.opts.set_logit_bound(bound);
    }

    /// The output mode this executor's plans are compiled to answer with.
    pub fn output_mode(&self) -> OutputMode {
        self.opts.output_mode
    }

    /// Allow the planner to cut refresh points where the modulus chain
    /// would be exhausted (DESIGN.md S21). Like
    /// [`WireExecutor::set_output_mode`], call before serving traffic —
    /// refresh-compiled plans cap the chain at `REFRESH_CHAIN_CAP`
    /// levels, so tenants must keygen against the same flag.
    pub fn set_refresh(&mut self, allow: bool, max_rounds: u32) {
        self.opts.allow_refresh = allow;
        self.opts.max_refresh_rounds = max_rounds;
    }

    /// Register (or replace) a tenant's evaluation keys. Fails — before
    /// anything is stored — if the bundle doesn't validate against its
    /// own parameter chain, so the tenant learns at registration, not on
    /// their first request.
    pub fn register(&self, tenant: &str, key_set: EvalKeySet) -> Result<Arc<TenantKeys>> {
        Ok(self.registry.register(tenant, TenantKeys::new(key_set)?))
    }

    fn count_plan_cache(&self, hit: bool) {
        if let Some(m) = &self.metrics {
            let field = if hit { &m.plan_cache_hits } else { &m.plan_cache_misses };
            field.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Get-or-build the tenant's session for `(variant, batch)`: validate
    /// the claimed batch against the variant's layout (**the ingress check
    /// for a forged `CtBundle::batch`** — it errors here, before any HE
    /// work), verify the registered keys match the serving geometry and
    /// cover the batch-compiled plan's rotations, then pre-encode the
    /// plan masks against the tenant's key-free engine.
    fn session(
        &self,
        tenant: &Arc<TenantKeys>,
        variant: &str,
        batch: usize,
    ) -> Result<Arc<WireSession>> {
        let skey = (variant.to_string(), batch);
        if let Some(s) = tenant.sessions.lock().unwrap().get(&skey) {
            // same metric semantics as HeExecutor: every request served
            // without a compile counts as a plan-cache hit
            self.count_plan_cache(true);
            return Ok(s.clone());
        }
        let model = self
            .models
            .get(variant)
            .ok_or_else(|| anyhow!("unknown variant {variant}"))?;
        let (layout, params) = session_geometry(model, self.opts)?;
        ensure!(
            batch >= 1 && batch <= layout.copies(),
            "request slot-batch {batch} outside 1..={} (variant {variant}'s \
             block copies) — rejected at ingress",
            layout.copies()
        );
        ensure!(
            tenant.key_set.params == params,
            "tenant keys were generated for a different parameter set than \
             variant {variant} (re-run keygen against this variant)"
        );
        let opts = PlanOptions { batch, ..self.opts };
        let key = PlanKey::new(model, &layout, opts);
        let cached = self.plans.lock().unwrap().get(&key).cloned();
        // Compile outside the locks: a cold plan compile + mask encoding
        // are the cold-start costs (the engine was built at registration).
        let engine = &tenant.engine;
        let chain = PlanChain::from_ctx(&engine.ctx);
        let (plan, was_cached) = plan_for(cached, model, layout, &chain, opts)?;
        self.count_plan_cache(was_cached);
        if !was_cached {
            if let Some(m) = &self.metrics {
                record_opt_metrics(m, &plan);
            }
            self.plans.lock().unwrap().entry(key).or_insert_with(|| plan.clone());
        }
        let needed = plan.required_rotations();
        ensure!(
            tenant.key_set.covers_rotations(&engine.encoder, &needed),
            "tenant keys do not cover the {} rotations of variant {variant}'s \
             batch-{batch} plan (keygen against this variant{})",
            needed.len(),
            if batch > 1 { " with --batch" } else { "" }
        );
        let prepared = PreparedPlan::new(plan, engine)?;
        // profile EWMAs (DESIGN.md S19) aggregate across tenants under the
        // same cache key the plan itself shares
        prepared.set_key(key);
        let session = Arc::new(WireSession { prepared });
        let session = {
            let mut sessions = tenant.sessions.lock().unwrap();
            sessions.entry(skey).or_insert(session).clone()
        };
        Ok(session)
    }

    /// The `NET_STATUS` backend slice (DESIGN.md S19): the shared plan
    /// cache, one JSON object per compiled plan, with the cache key's
    /// model hash resolved back to a variant name where one matches.
    /// Deliberately **not** per-tenant — the snapshot is unauthenticated,
    /// so it must never expose tenant identities, per-tenant session
    /// state, or anything derived from registered key material.
    pub fn status_json(&self) -> String {
        let mut entries: Vec<(PlanKey, usize, usize)> = self
            .plans
            .lock()
            .unwrap()
            .iter()
            .map(|(k, p)| (*k, p.ops.len(), p.waves.len()))
            .collect();
        entries.sort_by_key(|(k, ..)| (k.model_hash, k.batch, k.optimize));
        let variant_of: HashMap<u64, &str> = self
            .models
            .iter()
            .map(|(name, m)| (m.content_hash(), name.as_str()))
            .collect();
        let mut out = String::from("{\"plans\":[");
        for (i, (k, n_ops, n_waves)) in entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"model_hash\":\"{:016x}\",\"variant\":\"{}\",\"batch\":{},\
                 \"optimize\":{},\"ops\":{n_ops},\"waves\":{n_waves}}}",
                k.model_hash,
                crate::util::json_escape(variant_of.get(&k.model_hash).unwrap_or(&"?")),
                k.batch,
                k.optimize,
            ));
        }
        out.push_str("]}");
        out
    }
}

impl InferenceExecutor for WireExecutor {
    fn infer(&self, _variant: &str, _clip: &[f64]) -> Result<Vec<f64>> {
        bail!(
            "the he-wire tier holds no secret key and accepts no plaintext \
             clips — submit an EncryptedRequest (see `serve --tier he-wire`)"
        )
    }

    /// The variant layout's `copies()`: on this tier batching is
    /// client-side (one bundle carries the clips), so this is not a
    /// coalescing knob — it is the occupancy denominator, so a tenant
    /// shipping half-full bundles shows up as under-occupancy in the
    /// metrics instead of a fake 1.0.
    fn slot_capacity(&self, variant: &str) -> usize {
        cached_slot_capacity(&self.capacities, &self.models, self.opts, variant, |copies| {
            copies
        })
    }

    fn infer_encrypted(
        &self,
        variant: &str,
        tenant: &str,
        cts: &[Ciphertext],
        params_hash: Option<u64>,
        batch: usize,
        mode: OutputMode,
    ) -> Result<Ciphertext> {
        self.infer_encrypted_inner(variant, tenant, cts, params_hash, batch, mode, None)
    }

    fn infer_encrypted_with_refresh(
        &self,
        variant: &str,
        tenant: &str,
        cts: &[Ciphertext],
        params_hash: Option<u64>,
        batch: usize,
        mode: OutputMode,
        rounds: Option<Arc<dyn RefreshSource>>,
    ) -> Result<Ciphertext> {
        self.infer_encrypted_inner(variant, tenant, cts, params_hash, batch, mode, rounds)
    }
}

impl WireExecutor {
    /// Shared body of the two encrypted entry points: ingress checks,
    /// session lookup, residue scan, then plan execution — interactive
    /// through the request's [`RefreshSource`] when the serving plan
    /// carries refresh cut points (DESIGN.md S21), straight-line
    /// otherwise.
    #[allow(clippy::too_many_arguments)]
    fn infer_encrypted_inner(
        &self,
        variant: &str,
        tenant: &str,
        cts: &[Ciphertext],
        params_hash: Option<u64>,
        batch: usize,
        mode: OutputMode,
        rounds: Option<Arc<dyn RefreshSource>>,
    ) -> Result<Ciphertext> {
        // the requested mode must be the one the serving plans were
        // compiled for: a silent substitution would hand the client a
        // ciphertext whose slots mean something else than it asked for
        ensure!(
            mode == self.opts.output_mode,
            "output mode mismatch: request asked for {mode} but this tier's \
             serving plans are compiled for {} — re-send with the served \
             mode or restart the server with --output-mode {mode}",
            self.opts.output_mode
        );
        let entry = self
            .registry
            .get(tenant)
            .ok_or_else(|| anyhow!("tenant {tenant} has no registered EvalKeySet"))?;
        // the level/ring checks in execute() can't see prime mismatches —
        // the bundle's stamp is the cheap cross-chain rejection
        if let Some(h) = params_hash {
            ensure!(
                h == super::format::params_hash(&entry.key_set.params),
                "request ciphertexts were encrypted under a different \
                 parameter set than tenant {tenant}'s registered keys"
            );
        }
        // the claimed slot-batch size is untrusted: session() bounds it
        // against the variant's layout before any HE work runs
        let session = self.session(&entry, variant, batch)?;
        // full residue scan at the trust boundary: wire-deserialized
        // ciphertexts must be reduced before the unchecked modular
        // kernels see them (execute() itself only shape-checks — the
        // trusted in-process tier encrypts its own reduced inputs)
        ensure!(
            cts.iter()
                .all(|ct| ct.c0.is_reduced(&entry.engine.ctx) && ct.c1.is_reduced(&entry.engine.ctx)),
            "request ciphertext residues are not reduced modulo the chain"
        );
        let ct = if session.prepared.plan.has_refresh() {
            let src = rounds.ok_or_else(|| {
                anyhow!(
                    "variant {variant}'s serving plan carries {} refresh cut \
                     point(s) but the request did not open an interactive \
                     session (resend with --allow-refresh)",
                    session.prepared.plan.counts.refresh
                )
            })?;
            // fork the mask stream instead of holding the lock across the
            // round trips — interactive requests must not serialize on
            // each other's client latency
            let mut rng = {
                let mut shared = self.mask_rng.lock().unwrap();
                crate::util::Rng::seed_from_u64(shared.next_u64())
            };
            let (ct, _stats) = session.prepared.execute_with_refresh(
                &entry.engine,
                cts,
                self.threads,
                src.as_ref(),
                &mut rng,
            )?;
            ct
        } else {
            session.prepared.execute(&entry.engine, cts, self.threads)?
        };
        // decision accounting mirrors HeExecutor: sign-stage volume plus
        // one per-mode request count (DESIGN.md S20)
        if !matches!(mode, OutputMode::Logits) {
            if let (Some(m), Some(model)) = (&self.metrics, self.models.get(variant)) {
                let stages = sgn::sign_stage_count(mode, self.opts.sgn_preset, model.num_classes());
                m.sign_stages.fetch_add(stages, Ordering::Relaxed);
                let field = match mode {
                    OutputMode::Argmax => &m.decisions_argmax,
                    OutputMode::TopK(_) => &m.decisions_topk,
                    _ => &m.decisions_threshold,
                };
                field.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(ct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::wire::client::keygen;

    fn tiny() -> StgcnModel {
        StgcnModel::synthetic(Graph::ring(5), 8, 2, 3, &[4, 4], 3, 9)
    }

    fn executor(model: &StgcnModel, capacity: usize) -> WireExecutor {
        let mut models = HashMap::new();
        models.insert("v".to_string(), model.clone());
        WireExecutor::new(models, 2, Arc::new(KeyRegistry::new(capacity)))
    }

    #[test]
    fn test_wire_executor_rejects_plaintext_and_unknown_tenants() {
        let model = tiny();
        let ex = executor(&model, 4);
        assert!(ex.infer("v", &[0.0]).is_err(), "plaintext path must be closed");
        assert!(
            ex.infer_encrypted("v", "nobody", &[], None, 1, OutputMode::Logits).is_err(),
            "unregistered tenant must be rejected"
        );
    }

    #[test]
    fn test_wire_executor_serves_registered_tenant() {
        let model = tiny();
        let want = {
            let n = model.v() * model.c_in * model.t;
            let x: Vec<f64> = (0..n).map(|i| ((i * 37 % 101) as f64 - 50.0) / 80.0).collect();
            model.forward(&x).unwrap()
        };
        let ex = executor(&model, 4);
        let (client, key_set) = keygen(&model, "v", PlanOptions::default(), 11).unwrap();
        ex.register("alice", key_set).unwrap();
        let n = model.v() * model.c_in * model.t;
        let x: Vec<f64> = (0..n).map(|i| ((i * 37 % 101) as f64 - 50.0) / 80.0).collect();
        let cts = client.encrypt_clip(&x).unwrap();
        let hash = Some(crate::wire::params_hash(&client.params));
        // a wrong stamp is rejected before any HE work
        assert!(ex
            .infer_encrypted("v", "alice", &cts, Some(0xdead), 1, OutputMode::Logits)
            .is_err());
        let ct = ex.infer_encrypted("v", "alice", &cts, hash, 1, OutputMode::Logits).unwrap();
        let got = client.decrypt_logits(&ct).unwrap();
        let argmax = crate::util::argmax;
        assert_eq!(argmax(&got), argmax(&want));
        assert!(ex
            .infer_encrypted("missing", "alice", &cts, hash, 1, OutputMode::Logits)
            .is_err());
    }

    #[test]
    fn test_status_json_lists_compiled_plans_without_tenant_names() {
        let model = tiny();
        let ex = executor(&model, 4);
        assert_eq!(ex.status_json(), "{\"plans\":[]}");
        let (client, key_set) = keygen(&model, "v", PlanOptions::default(), 17).unwrap();
        ex.register("alice", key_set).unwrap();
        let n = model.v() * model.c_in * model.t;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 / 7.0).sin()).collect();
        let cts = client.encrypt_clip(&x).unwrap();
        ex.infer_encrypted("v", "alice", &cts, None, 1, OutputMode::Logits).unwrap();
        let json = ex.status_json();
        assert!(json.contains("\"variant\":\"v\""), "{json}");
        assert!(json.contains("\"batch\":1"), "{json}");
        // S19 threat model: the snapshot is unauthenticated — no tenant
        // identities or key-derived state may appear
        assert!(!json.contains("alice"), "{json}");
    }

    #[test]
    fn test_forged_batch_rejected_at_ingress_before_he_work() {
        let model = tiny();
        let ex = executor(&model, 4);
        let (client, key_set) = keygen(&model, "v", PlanOptions::default(), 13).unwrap();
        ex.register("alice", key_set).unwrap();
        let n = model.v() * model.c_in * model.t;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 / 9.0).cos()).collect();
        let cts = client.encrypt_clip(&x).unwrap();
        let copies = client.spec.copies();
        let hash = Some(crate::wire::params_hash(&client.params));
        // batch = 0 and batch > copies() both error cleanly at ingress
        for forged in [0usize, copies + 1, usize::MAX] {
            let err = ex
                .infer_encrypted("v", "alice", &cts, hash, forged, OutputMode::Logits)
                .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("ingress") || msg.contains("outside 1..="), "{msg}");
        }
        // a *plausible* forged batch (> 1 but within copies) on keys cut
        // for the single-clip plan is refused by rotation coverage — it
        // never executes, so it can never mis-slice logits
        let err = ex
            .infer_encrypted("v", "alice", &cts, hash, 2, OutputMode::Logits)
            .unwrap_err();
        assert!(format!("{err:#}").contains("do not cover"), "{err:#}");
    }

    #[test]
    fn test_output_mode_mismatch_rejected_before_any_he_work() {
        let model = tiny();
        let mut ex = executor(&model, 4);
        assert_eq!(ex.output_mode(), OutputMode::Logits);
        // the mode check fires before the registry lookup — no tenant, no
        // keys, no HE work, yet the error is the typed mode mismatch
        let err = ex
            .infer_encrypted("v", "alice", &[], None, 1, OutputMode::Argmax)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("output mode mismatch"), "{msg}");
        assert!(msg.contains("compiled for logits"), "{msg}");
        // flipping the served mode flips which requests are refused
        ex.set_output_mode(OutputMode::Argmax, SgnPreset::Fast, 4.0);
        assert_eq!(ex.output_mode(), OutputMode::Argmax);
        let err = ex
            .infer_encrypted("v", "alice", &[], None, 1, OutputMode::Logits)
            .unwrap_err();
        assert!(format!("{err:#}").contains("output mode mismatch"), "{err:#}");
    }
}
