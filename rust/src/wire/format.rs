//! Wire serialization of the CKKS public objects (DESIGN.md S15): the
//! parameter set, the public encryption key, key-switching keys, the
//! evaluation-key bundle a client registers with the server, and
//! ciphertexts (single and bundled). Secret material has exactly one
//! serializable holder — `wire::client::ClientKeys` — and it is never
//! part of any server-facing record.

use super::codec::{
    frame_with, unframe, ByteReader, ByteWriter, KIND_CIPHERTEXT, KIND_CT_BUNDLE,
    KIND_EVAL_KEY_SET, KIND_KSWITCH_KEY, KIND_PARAMS, KIND_PUBLIC_KEY,
};
use crate::ckks::keys::KskDigit;
use crate::ckks::poly::RnsPoly;
use crate::ckks::{Ciphertext, CkksParams, EvalEngine, EvalKeys, KeySwitchKey, PublicKey};
use crate::he_infer::OutputMode;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Largest ring degree a reader will accept (paper scale is 2^16; this
/// caps allocation from a forged-but-checksummed frame).
const MAX_N: usize = 1 << 22;
/// Largest limb count a reader will accept.
const MAX_LIMBS: usize = 128;

/// Uniform `to_bytes`/`from_bytes` surface over the framed codec. Every
/// implementor owns one record kind; `from_bytes` verifies the frame
/// checksum before parsing and rejects trailing payload bytes after.
pub trait WireSerialize: Sized {
    const KIND: u8;

    fn write_payload(&self, w: &mut ByteWriter);
    fn read_payload(r: &mut ByteReader) -> Result<Self>;

    fn to_bytes(&self) -> Vec<u8> {
        frame_with(Self::KIND, |w| self.write_payload(w))
    }

    fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let payload = unframe(Self::KIND, bytes)?;
        let mut r = ByteReader::new(payload);
        let v = Self::read_payload(&mut r)?;
        r.finish()?;
        Ok(v)
    }
}

// ------------------------------------------------------------ primitives

pub(crate) fn write_poly(w: &mut ByteWriter, p: &RnsPoly) {
    let n = p.limbs.first().map(|l| l.len()).unwrap_or(0);
    w.put_u32(n as u32);
    w.put_u32(p.nq as u32);
    w.put_u8(p.has_special as u8);
    w.put_u8(p.is_ntt as u8);
    for limb in &p.limbs {
        debug_assert_eq!(limb.len(), n);
        w.put_u64_slice(limb);
    }
}

pub(crate) fn read_poly(r: &mut ByteReader) -> Result<RnsPoly> {
    let n = r.u32()? as usize;
    let nq = r.u32()? as usize;
    let has_special = r.flag()?;
    let is_ntt = r.flag()?;
    ensure!(
        n.is_power_of_two() && (8..=MAX_N).contains(&n),
        "wire poly: bad ring degree {n}"
    );
    let count = nq + has_special as usize;
    ensure!(
        nq >= 1 && count <= MAX_LIMBS,
        "wire poly: bad limb count nq={nq} special={has_special}"
    );
    let limbs = (0..count).map(|_| r.vec_u64(n)).collect::<Result<Vec<_>>>()?;
    Ok(RnsPoly {
        limbs,
        nq,
        has_special,
        is_ntt,
    })
}

fn write_params_payload(w: &mut ByteWriter, p: &CkksParams) {
    w.put_u64(p.n as u64);
    w.put_u32(p.q0_bits);
    w.put_u32(p.scale_bits);
    w.put_u64(p.levels as u64);
    w.put_u32(p.special_bits);
    w.put_u8(p.allow_insecure as u8);
}

fn read_params_payload(r: &mut ByteReader) -> Result<CkksParams> {
    let n = r.u64()? as usize;
    let q0_bits = r.u32()?;
    let scale_bits = r.u32()?;
    let levels = r.u64()? as usize;
    let special_bits = r.u32()?;
    let allow_insecure = r.flag()?;
    ensure!(
        n.is_power_of_two() && (8..=MAX_N).contains(&n),
        "wire params: bad ring degree {n}"
    );
    ensure!(
        (1..MAX_LIMBS).contains(&levels),
        "wire params: bad level count {levels}"
    );
    // mirror zq::gen_ntt_primes' accepted range so a forged frame errors
    // here instead of tripping an assert inside params.build()
    ensure!(
        [q0_bits, scale_bits, special_bits]
            .iter()
            .all(|b| (20..=61).contains(b)),
        "wire params: prime bit widths out of range"
    );
    Ok(CkksParams {
        n,
        q0_bits,
        scale_bits,
        levels,
        special_bits,
        allow_insecure,
    })
}

/// Serialize an output mode as its (tag, aux, cutoff_bits) wire triple —
/// the one encoding shared by `CtBundle`, the `NET_INFER` header, and the
/// `NET_DECISION` response (DESIGN.md S20).
pub(crate) fn write_output_mode(w: &mut ByteWriter, mode: OutputMode) {
    w.put_u8(mode.tag());
    w.put_u32(mode.aux());
    w.put_u64(mode.cutoff_bits());
}

/// Parse an output-mode triple, rejecting forged tags and non-finite
/// threshold cutoffs typed (`OutputMode::from_wire` never panics).
pub(crate) fn read_output_mode(r: &mut ByteReader) -> Result<OutputMode> {
    let tag = r.u8()?;
    let aux = r.u32()?;
    let cutoff_bits = r.u64()?;
    OutputMode::from_wire(tag, aux, cutoff_bits)
}

/// Content hash of a parameter set — stamped into ciphertext bundles so a
/// server can cheaply reject ciphertexts that were encrypted under a
/// different modulus chain than the tenant's registered keys.
pub fn params_hash(p: &CkksParams) -> u64 {
    let mut w = ByteWriter::new();
    write_params_payload(&mut w, p);
    super::codec::fnv1a64(w.as_bytes())
}

fn write_kswitch_payload(w: &mut ByteWriter, k: &KeySwitchKey) {
    w.put_u32(k.digits.len() as u32);
    for d in &k.digits {
        write_poly(w, &d.b);
        write_poly(w, &d.a);
    }
}

fn read_kswitch_payload(r: &mut ByteReader) -> Result<KeySwitchKey> {
    let n = r.u32()? as usize;
    ensure!(
        (1..=MAX_LIMBS).contains(&n),
        "wire key-switch key: bad digit count {n}"
    );
    let digits = (0..n)
        .map(|_| {
            let b = read_poly(r)?;
            let a = read_poly(r)?;
            // hybrid key-switch digits always live in NTT form over Q∪{P};
            // reject other shapes before they can trip evaluator asserts
            ensure!(
                b.is_ntt && a.is_ntt && b.has_special && a.has_special && b.nq == a.nq,
                "wire key-switch key: digit shape mismatch"
            );
            Ok(KskDigit { b, a })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(KeySwitchKey { digits })
}

// ------------------------------------------------------------- per-type

impl WireSerialize for CkksParams {
    const KIND: u8 = KIND_PARAMS;

    fn write_payload(&self, w: &mut ByteWriter) {
        write_params_payload(w, self);
    }

    fn read_payload(r: &mut ByteReader) -> Result<Self> {
        read_params_payload(r)
    }
}

impl WireSerialize for PublicKey {
    const KIND: u8 = KIND_PUBLIC_KEY;

    fn write_payload(&self, w: &mut ByteWriter) {
        write_poly(w, &self.b);
        write_poly(w, &self.a);
    }

    fn read_payload(r: &mut ByteReader) -> Result<Self> {
        let b = read_poly(r)?;
        let a = read_poly(r)?;
        Ok(PublicKey { b, a })
    }
}

impl WireSerialize for KeySwitchKey {
    const KIND: u8 = KIND_KSWITCH_KEY;

    fn write_payload(&self, w: &mut ByteWriter) {
        write_kswitch_payload(w, self);
    }

    fn read_payload(r: &mut ByteReader) -> Result<Self> {
        read_kswitch_payload(r)
    }
}

impl WireSerialize for Ciphertext {
    const KIND: u8 = KIND_CIPHERTEXT;

    fn write_payload(&self, w: &mut ByteWriter) {
        w.put_f64(self.scale);
        write_poly(w, &self.c0);
        write_poly(w, &self.c1);
    }

    fn read_payload(r: &mut ByteReader) -> Result<Self> {
        let scale = r.f64()?;
        let c0 = read_poly(r)?;
        let c1 = read_poly(r)?;
        ensure!(
            c0.nq == c1.nq && !c0.has_special && !c1.has_special,
            "wire ciphertext: component shape mismatch"
        );
        // ciphertexts travel in evaluation form; rejecting here keeps a
        // crafted frame from tripping domain asserts inside the evaluator
        ensure!(
            c0.is_ntt && c1.is_ntt,
            "wire ciphertext: components must be in NTT form"
        );
        ensure!(
            scale.is_finite() && scale > 0.0,
            "wire ciphertext: invalid scale"
        );
        Ok(Ciphertext { c0, c1, scale })
    }
}

// ------------------------------------------------------------ eval keys

/// The complete key material a client publishes to the serving side: the
/// parameter set (the server rebuilds the modulus chain from it — prime
/// generation is deterministic), the relinearization key, and Galois keys
/// for exactly the rotations of the variant's compiled plan
/// (`HePlan::required_rotations`). **No secret key, no public encryption
/// key**: a server holding only an `EvalKeySet` can evaluate, but can
/// neither decrypt nor encrypt under the client's key.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalKeySet {
    /// Variant the Galois subset was generated for (e.g. `lingcn-nl2`).
    pub variant: String,
    pub params: CkksParams,
    /// Shared, not cloned: key bundles are MiB-scale and every engine
    /// built from this set reuses the same allocation.
    pub keys: Arc<EvalKeys>,
}

impl EvalKeySet {
    /// Extract the shippable key half from a full (trusted-process)
    /// engine — used by demos and tests; the split-process path generates
    /// it directly via `wire::client::ClientKeys::generate`.
    pub fn from_engine(engine: &crate::ckks::CkksEngine, variant: &str) -> Self {
        EvalKeySet {
            variant: variant.to_string(),
            params: engine.ctx.params.clone(),
            keys: engine.eval.keys.clone(),
        }
    }

    /// Build the server-half engine: modulus chain + NTT tables from the
    /// params, evaluator over these keys. The resulting [`EvalEngine`]
    /// contains no secret key *by type*. The frame checksum is integrity,
    /// not authenticity, so this is the trust boundary for key material:
    /// every key-switch key must have exactly one digit per chain prime,
    /// full-chain extended-basis polynomials of the chain's ring degree,
    /// and reduced residues — otherwise a crafted bundle would panic the
    /// evaluator mid-request instead of failing registration.
    pub fn build_engine(&self) -> Result<EvalEngine> {
        let ctx = self.params.build()?;
        let k = ctx.moduli.len();
        let well_formed = |ksk: &KeySwitchKey| {
            ksk.digits.len() == k
                && ksk.digits.iter().all(|d| {
                    d.b.nq == k
                        && d.a.nq == k
                        && d.b.limbs.iter().chain(d.a.limbs.iter()).all(|l| l.len() == ctx.n)
                        && d.b.is_reduced(&ctx)
                        && d.a.is_reduced(&ctx)
                })
        };
        ensure!(
            well_formed(&self.keys.relin) && self.keys.galois.values().all(well_formed),
            "eval-key bundle does not match the parameter chain \
             (digit count, limb shape, or unreduced residues)"
        );
        Ok(EvalEngine::new(ctx, self.keys.clone()))
    }

    /// Whether this bundle carries a Galois key for every rotation step in
    /// `steps` (the plan's `required_rotations`).
    pub fn covers_rotations(&self, encoder: &crate::ckks::Encoder, steps: &[usize]) -> bool {
        steps
            .iter()
            .all(|&k| self.keys.galois.contains_key(&encoder.rotation_galois_element(k)))
    }
}

impl WireSerialize for EvalKeySet {
    const KIND: u8 = KIND_EVAL_KEY_SET;

    fn write_payload(&self, w: &mut ByteWriter) {
        w.put_str(&self.variant);
        write_params_payload(w, &self.params);
        write_kswitch_payload(w, &self.keys.relin);
        // galois map in sorted element order: byte-stable output
        let mut elems: Vec<&usize> = self.keys.galois.keys().collect();
        elems.sort_unstable();
        w.put_u32(elems.len() as u32);
        for &g in elems {
            w.put_u64(g as u64);
            write_kswitch_payload(w, &self.keys.galois[&g]);
        }
    }

    fn read_payload(r: &mut ByteReader) -> Result<Self> {
        let variant = r.str()?;
        let params = read_params_payload(r)?;
        let relin = read_kswitch_payload(r)?;
        let count = r.u32()? as usize;
        let mut galois = HashMap::with_capacity(count.min(1024));
        for _ in 0..count {
            let g = r.u64()? as usize;
            let key = read_kswitch_payload(r)?;
            ensure!(
                galois.insert(g, key).is_none(),
                "wire eval-key set: duplicate Galois element {g}"
            );
        }
        Ok(EvalKeySet {
            variant,
            params,
            keys: Arc::new(EvalKeys { relin, galois }),
        })
    }
}

// ------------------------------------------------------------ ct bundle

/// Largest slot-batch size a reader will accept (paper-scale slot counts
/// cap `copies()` well below this; the executor additionally rejects any
/// batch above the variant layout's real `copies()`). Public because the
/// TCP tier enforces the same bound on `NET_INFER` headers.
pub const MAX_BATCH: usize = 4096;

/// A request's ciphertexts (one per graph node), stamped with the hash of
/// the parameter set they were encrypted under and the slot-batch size
/// the client packed (DESIGN.md S16). The `batch` field is untrusted
/// input like everything else on the wire: readers bound it here, and
/// `WireExecutor::infer_encrypted` rejects values the variant's layout
/// cannot hold **before any HE work** — a forged batch errors at
/// ingress; it can never mis-slice another clip's logits because
/// block-closed plans keep every copy's dataflow inside its own copy.
#[derive(Clone, Debug, PartialEq)]
pub struct CtBundle {
    pub params_hash: u64,
    /// Distinct clips slot-packed into the block copies (1 = the legacy
    /// replicated single-clip layout).
    pub batch: usize,
    /// Output mode the client is requesting for this inference (v3;
    /// DESIGN.md S20). The serving side rejects a mode the registered
    /// plan was not compiled for — it never silently substitutes.
    pub mode: OutputMode,
    pub cts: Vec<Ciphertext>,
}

impl CtBundle {
    pub fn new(params: &CkksParams, cts: Vec<Ciphertext>) -> Self {
        Self::new_batched(params, cts, 1)
    }

    /// A bundle carrying `batch` slot-packed clips.
    pub fn new_batched(params: &CkksParams, cts: Vec<Ciphertext>, batch: usize) -> Self {
        CtBundle {
            params_hash: params_hash(params),
            batch,
            mode: OutputMode::Logits,
            cts,
        }
    }

    /// Stamp the requested output mode (builder-style; defaults to
    /// `Logits`, the pre-v3 behavior).
    pub fn with_mode(mut self, mode: OutputMode) -> Self {
        self.mode = mode;
        self
    }

    /// Reject a bundle encrypted under a different parameter set.
    pub fn check_params(&self, params: &CkksParams) -> Result<()> {
        ensure!(
            self.params_hash == params_hash(params),
            "ciphertext bundle was encrypted under a different parameter set"
        );
        Ok(())
    }
}

impl WireSerialize for CtBundle {
    const KIND: u8 = KIND_CT_BUNDLE;

    fn write_payload(&self, w: &mut ByteWriter) {
        w.put_u64(self.params_hash);
        w.put_u32(self.batch as u32);
        write_output_mode(w, self.mode);
        w.put_u32(self.cts.len() as u32);
        for ct in &self.cts {
            ct.write_payload(w);
        }
    }

    fn read_payload(r: &mut ByteReader) -> Result<Self> {
        let params_hash = r.u64()?;
        let batch = r.u32()? as usize;
        ensure!(
            (1..=MAX_BATCH).contains(&batch),
            "wire ciphertext bundle: bad slot-batch size {batch}"
        );
        let mode = read_output_mode(r)?;
        let count = r.u32()? as usize;
        ensure!(
            (1..=4096).contains(&count),
            "wire ciphertext bundle: bad ciphertext count {count}"
        );
        let cts = (0..count)
            .map(|_| Ciphertext::read_payload(r))
            .collect::<Result<Vec<_>>>()?;
        Ok(CtBundle { params_hash, batch, mode, cts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::{build_eval_keys, CkksEngine};

    fn tiny_engine() -> CkksEngine {
        let mut p = CkksParams::toy(2);
        p.n = 1 << 7;
        CkksEngine::new(p, &[1, 3], 5).unwrap()
    }

    #[test]
    fn test_params_roundtrip_and_hash() {
        let p = CkksParams::toy(4);
        let back = CkksParams::from_bytes(&p.to_bytes()).unwrap();
        assert_eq!(p, back);
        assert_eq!(params_hash(&p), params_hash(&back));
        let q = CkksParams::toy(5);
        assert_ne!(params_hash(&p), params_hash(&q));
    }

    #[test]
    fn test_public_key_roundtrip() {
        let e = tiny_engine();
        let back = PublicKey::from_bytes(&e.pk.to_bytes()).unwrap();
        assert_eq!(e.pk, back);
    }

    #[test]
    fn test_ciphertext_roundtrip_preserves_bits() {
        let e = tiny_engine();
        let ct = e.encrypt(&[0.5, -1.25, 3.0]);
        let back = Ciphertext::from_bytes(&ct.to_bytes()).unwrap();
        assert_eq!(ct, back);
        assert_eq!(e.decrypt(&ct), e.decrypt(&back));
    }

    #[test]
    fn test_eval_key_set_roundtrip() {
        let e = tiny_engine();
        let ks = EvalKeySet::from_engine(&e, "lingcn-nl2");
        let back = EvalKeySet::from_bytes(&ks.to_bytes()).unwrap();
        assert_eq!(ks, back);
        assert!(back.covers_rotations(&e.encoder, &[1, 3]));
        assert!(!back.covers_rotations(&e.encoder, &[1, 2]));
    }

    #[test]
    fn test_eval_key_set_bytes_are_deterministic() {
        // the galois map is a HashMap, but the wire bytes must not depend
        // on its iteration order
        let mut p = CkksParams::toy(2);
        p.n = 1 << 7;
        let ctx = p.build().unwrap();
        let enc = crate::ckks::Encoder::new(ctx.n);
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let sk = crate::ckks::keys::keygen_secret(&ctx, &mut rng);
        let keys = build_eval_keys(&ctx, &enc, &sk, &[1, 2, 5, 9], false, &mut rng);
        let ks = EvalKeySet {
            variant: "v".into(),
            params: p,
            keys: Arc::new(keys),
        };
        assert_eq!(ks.to_bytes(), EvalKeySet::from_bytes(&ks.to_bytes()).unwrap().to_bytes());
    }

    #[test]
    fn test_ct_bundle_roundtrip_and_params_check() {
        let e = tiny_engine();
        let cts = vec![e.encrypt(&[1.0]), e.encrypt(&[2.0])];
        let bundle = CtBundle::new(&e.ctx.params, cts);
        assert_eq!(bundle.batch, 1);
        let back = CtBundle::from_bytes(&bundle.to_bytes()).unwrap();
        assert_eq!(bundle, back);
        back.check_params(&e.ctx.params).unwrap();
        assert!(back.check_params(&CkksParams::toy(7)).is_err());
    }

    #[test]
    fn test_batched_ct_bundle_roundtrip_and_batch_bounds() {
        let e = tiny_engine();
        let cts = vec![e.encrypt(&[1.0]), e.encrypt(&[2.0])];
        let bundle = CtBundle::new_batched(&e.ctx.params, cts.clone(), 3);
        let back = CtBundle::from_bytes(&bundle.to_bytes()).unwrap();
        assert_eq!(back.batch, 3);
        assert_eq!(bundle, back);
        // a zero or absurd batch is rejected at the reader, not later
        for bad_batch in [0usize, MAX_BATCH + 1, u32::MAX as usize] {
            let forged = CtBundle {
                params_hash: bundle.params_hash,
                batch: bad_batch,
                mode: OutputMode::Logits,
                cts: cts.clone(),
            };
            assert!(
                CtBundle::from_bytes(&forged.to_bytes()).is_err(),
                "batch {bad_batch} must be rejected at ingress"
            );
        }
    }

    #[test]
    fn test_ct_bundle_mode_roundtrip_and_forged_mode_rejected() {
        let e = tiny_engine();
        let cts = vec![e.encrypt(&[1.0])];
        for mode in [
            OutputMode::Logits,
            OutputMode::Argmax,
            OutputMode::TopK(2),
            OutputMode::Threshold { class: 1, cutoff_bits: 0.25f64.to_bits() },
        ] {
            let bundle = CtBundle::new(&e.ctx.params, cts.clone()).with_mode(mode);
            let back = CtBundle::from_bytes(&bundle.to_bytes()).unwrap();
            assert_eq!(back.mode, mode);
            assert_eq!(bundle, back);
        }
        // a forged mode tag or a non-finite threshold cutoff is rejected
        // at the reader, typed, before any ciphertext is parsed
        let forge = |mode_tag: u8, cutoff_bits: u64| {
            let good = CtBundle::new(&e.ctx.params, cts.clone());
            let bytes = frame_with(KIND_CT_BUNDLE, |w| {
                w.put_u64(good.params_hash);
                w.put_u32(1);
                w.put_u8(mode_tag);
                w.put_u32(0);
                w.put_u64(cutoff_bits);
                w.put_u32(good.cts.len() as u32);
                for ct in &good.cts {
                    ct.write_payload(w);
                }
            });
            CtBundle::from_bytes(&bytes)
        };
        let err = forge(9, 0).unwrap_err().to_string();
        assert!(err.contains("unknown output-mode tag 9"), "got: {err}");
        let err = forge(3, f64::NAN.to_bits()).unwrap_err().to_string();
        assert!(err.contains("not a finite number"), "got: {err}");
    }

    #[test]
    fn test_corrupt_key_material_is_rejected_not_panicking() {
        let e = tiny_engine();
        let ks = EvalKeySet::from_engine(&e, "v");
        let bytes = ks.to_bytes();
        for cut in [0usize, 10, 24, bytes.len() / 3, bytes.len() - 1] {
            assert!(EvalKeySet::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        for pos in (0..bytes.len()).step_by(97) {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            assert!(EvalKeySet::from_bytes(&bad).is_err(), "flip at {pos}");
        }
    }
}
