//! Offline API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no network or registry access, so this shim is
//! vendored as a path dependency. It implements exactly the surface the
//! `lingcn` crate uses:
//!
//! * [`Error`] — an opaque error value carrying a human-readable cause
//!   chain (outermost context first);
//! * [`Result<T>`] — alias with `Error` as the default error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: that is what permits the blanket
//! `impl From<E: std::error::Error> for Error` used by `?` without
//! colliding with the reflexive `From<T> for T`.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of display messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost (most recently attached) message.
    pub fn root_context(&self) -> &str {
        &self.chain[0]
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "Condition failed: `{}`",
                ::std::stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("not an integer")?;
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn test_question_mark_and_context() {
        assert_eq!(parse("17").unwrap(), 17);
        let e = parse("x").unwrap_err();
        assert_eq!(e.root_context(), "not an integer");
        assert!(e.chain().count() >= 2, "source preserved in chain");
        let e2 = parse("-3").unwrap_err();
        assert_eq!(e2.to_string(), "negative: -3");
    }

    #[test]
    fn test_option_context_and_bail() {
        fn first(v: &[u8]) -> Result<u8> {
            let x = v.first().context("empty")?;
            if *x == 0 {
                bail!("zero");
            }
            Ok(*x)
        }
        assert_eq!(first(&[5]).unwrap(), 5);
        assert_eq!(first(&[]).unwrap_err().to_string(), "empty");
        assert_eq!(first(&[0]).unwrap_err().to_string(), "zero");
    }

    #[test]
    fn test_ensure_bare_condition() {
        fn check(x: u32) -> Result<()> {
            ensure!(x > 1);
            Ok(())
        }
        let e = check(0).unwrap_err();
        assert!(e.to_string().contains("x > 1"), "{e}");
    }

    #[test]
    fn test_error_context_stacks_and_debug_formats() {
        let base: Error = "boom".parse::<i32>().unwrap_err().into();
        let wrapped = base.context("inner").context("outer");
        assert_eq!(wrapped.to_string(), "outer");
        let dbg = format!("{wrapped:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn test_anyhow_macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("captured {n}");
        assert_eq!(b.to_string(), "captured 3");
        let c = anyhow!("fmt {} {}", 1, 2);
        assert_eq!(c.to_string(), "fmt 1 2");
    }
}
